"""The paper's rewriter tool: any TabFile configuration → any other.

Streams row groups (bounded memory), re-buckets rows to the target
``rows_per_rg``, re-runs encoding selection and the compression gate under
the target config, and records before/after accounting.  Matches the
paper's §5 overhead discussion: multithreaded, offline, one-time, and —
because the optimized config usually *shrinks* the file — storage-neutral.
"""

from __future__ import annotations

import dataclasses
import time

from repro.core.config import FileConfig
from repro.core.metadata import FileMeta
from repro.core.reader import TabFileReader
from repro.core.table import Table
from repro.core.writer import TabFileWriter


@dataclasses.dataclass
class RewriteReport:
    src_path: str
    dst_path: str
    seconds: float
    rows: int
    src_stored_bytes: int
    dst_stored_bytes: int
    src_describe: dict
    dst_describe: dict

    @property
    def size_ratio(self) -> float:
        return self.dst_stored_bytes / max(1, self.src_stored_bytes)

    @property
    def rewrite_bandwidth(self) -> float:
        """Logical bytes re-written per second."""
        return self.src_describe["logical_nbytes"] / max(1e-9, self.seconds)


def rewrite_file(src_path: str, dst_path: str, config: FileConfig,
                 threads: int = 4,
                 columns: list[str] | None = None) -> RewriteReport:
    t0 = time.perf_counter()
    reader = TabFileReader(src_path)
    src_meta = reader.meta
    names = columns if columns is not None else src_meta.schema.names
    from repro.core.schema import Schema
    schema = Schema([src_meta.schema.field(n) for n in names])

    writer = TabFileWriter(dst_path, config, threads=threads).begin(schema)
    pending: list[Table] = []
    pending_rows = 0

    def flush(n_target: int) -> None:
        nonlocal pending, pending_rows
        while pending_rows >= n_target:
            buf = pending[0] if len(pending) == 1 else Table.concat(pending)
            writer.write_row_group(buf.slice(0, n_target))
            rest = buf.slice(n_target, buf.num_rows)
            pending = [rest] if rest.num_rows > 0 else []
            pending_rows = rest.num_rows

    for rg_idx in range(len(src_meta.row_groups)):
        tbl = reader.read_table(columns=names, row_groups=[rg_idx])
        pending.append(tbl)
        pending_rows += tbl.num_rows
        flush(config.rows_per_rg)
    if pending_rows > 0:
        buf = pending[0] if len(pending) == 1 else Table.concat(pending)
        writer.write_row_group(buf)
    dst_meta = writer.finish()

    seconds = time.perf_counter() - t0
    return RewriteReport(
        src_path=src_path, dst_path=dst_path, seconds=seconds,
        rows=src_meta.num_rows,
        src_stored_bytes=src_meta.stored_bytes,
        dst_stored_bytes=dst_meta.stored_bytes,
        src_describe={**src_meta.describe(),
                      "logical_nbytes": src_meta.logical_nbytes},
        dst_describe={**dst_meta.describe(),
                      "logical_nbytes": dst_meta.logical_nbytes},
    )
