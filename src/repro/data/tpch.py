"""Seeded synthetic TPC-H generator (lineitem + orders).

Faithful to dbgen's column types and value distributions at the level the
paper's experiments depend on: sorted orderkeys (delta-friendly), low-
cardinality dictionary columns (quantity, discount, flags, modes), dates in
1992–1998, and free-text comments.  Scale factor 1 ≈ 6M lineitem rows;
generation is chunked so arbitrarily large SFs stream to disk at bounded
memory through the streaming writer.

Dates are int32 days since 1992-01-01.
"""

from __future__ import annotations

import os
from collections.abc import Iterator

import numpy as np

from repro.core.config import FileConfig
from repro.core.metadata import FileMeta
from repro.core.schema import Field, LogicalType, PhysicalType, Schema
from repro.core.table import StringColumn, Table
from repro.core.writer import TabFileWriter

LINEITEM_ROWS_PER_SF = 6_000_000
ORDERS_ROWS_PER_SF = 1_500_000

SHIPMODES = ["REG AIR", "AIR", "MAIL", "RAIL", "SHIP", "TRUCK", "FOB"]
PRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
_WORDS = ("the quick final pending special express ironic regular bold "
          "furious careful silent even blithe dogged").split()


def _comments(rng: np.random.Generator, n: int) -> StringColumn:
    w = rng.integers(0, len(_WORDS), size=(n, 3))
    vals = [f"{_WORDS[a]} {_WORDS[b]} {_WORDS[c]}" for a, b, c in w]
    return StringColumn.from_pylist(vals)


def lineitem_schema(include_strings: bool = True) -> Schema:
    fields = [
        Field("l_orderkey", PhysicalType.INT64),
        Field("l_partkey", PhysicalType.INT32),
        Field("l_suppkey", PhysicalType.INT32),
        Field("l_linenumber", PhysicalType.INT32),
        Field("l_quantity", PhysicalType.FLOAT),
        Field("l_extendedprice", PhysicalType.FLOAT),
        Field("l_discount", PhysicalType.FLOAT),
        Field("l_tax", PhysicalType.FLOAT),
        Field("l_returnflag", PhysicalType.INT32),
        Field("l_linestatus", PhysicalType.INT32),
        Field("l_shipdate", PhysicalType.INT32, LogicalType.DATE),
        Field("l_commitdate", PhysicalType.INT32, LogicalType.DATE),
        Field("l_receiptdate", PhysicalType.INT32, LogicalType.DATE),
        Field("l_shipinstruct", PhysicalType.INT32),
        Field("l_shipmode", PhysicalType.INT32),
    ]
    if include_strings:
        fields.append(Field("l_comment", PhysicalType.BYTE_ARRAY,
                            LogicalType.STRING))
    return Schema(fields)


def orders_schema(include_strings: bool = True) -> Schema:
    fields = [
        Field("o_orderkey", PhysicalType.INT64),
        Field("o_custkey", PhysicalType.INT32),
        Field("o_orderstatus", PhysicalType.INT32),
        Field("o_totalprice", PhysicalType.FLOAT),
        Field("o_orderdate", PhysicalType.INT32, LogicalType.DATE),
        Field("o_orderpriority", PhysicalType.INT32),
        Field("o_shippriority", PhysicalType.INT32),
    ]
    if include_strings:
        fields.append(Field("o_comment", PhysicalType.BYTE_ARRAY,
                            LogicalType.STRING))
    return Schema(fields)


def _gen_orders_chunk(rng: np.random.Generator, key_start: int, n: int,
                      include_strings: bool) -> Table:
    cols: dict[str, object] = {
        "o_orderkey": np.arange(key_start, key_start + n, dtype=np.int64),
        "o_custkey": rng.integers(1, 150_000, n).astype(np.int32),
        "o_orderstatus": rng.integers(0, 3, n).astype(np.int32),
        "o_totalprice": (rng.random(n).astype(np.float32) * 400_000
                         + 1_000).round(2).astype(np.float32),
        "o_orderdate": rng.integers(0, 2405, n).astype(np.int32),
        "o_orderpriority": rng.integers(0, 5, n).astype(np.int32),
        "o_shippriority": np.zeros(n, dtype=np.int32),
    }
    if include_strings:
        cols["o_comment"] = _comments(rng, n)
    return Table(cols, orders_schema(include_strings))


def _gen_lineitem_chunk(rng: np.random.Generator, orders: Table,
                        include_strings: bool) -> Table:
    n_orders = orders.num_rows
    lines = rng.integers(1, 8, n_orders)
    n = int(lines.sum())
    okey = np.repeat(np.asarray(orders["o_orderkey"]), lines)
    odate = np.repeat(np.asarray(orders["o_orderdate"]), lines)
    linenumber = np.concatenate(
        [np.arange(1, k + 1, dtype=np.int32) for k in lines]) \
        if n_orders else np.zeros(0, np.int32)
    qty = rng.integers(1, 51, n).astype(np.float32)
    ship = (odate + rng.integers(1, 122, n)).astype(np.int32)
    cols: dict[str, object] = {
        "l_orderkey": okey.astype(np.int64),
        "l_partkey": rng.integers(1, 200_000, n).astype(np.int32),
        "l_suppkey": rng.integers(1, 10_000, n).astype(np.int32),
        "l_linenumber": linenumber,
        "l_quantity": qty,
        "l_extendedprice": (qty * (rng.random(n).astype(np.float32)
                                   * 2_000 + 900)).round(2
                                                        ).astype(np.float32),
        "l_discount": (rng.integers(0, 11, n) / 100.0).astype(np.float32),
        "l_tax": (rng.integers(0, 9, n) / 100.0).astype(np.float32),
        "l_returnflag": rng.integers(0, 3, n).astype(np.int32),
        "l_linestatus": rng.integers(0, 2, n).astype(np.int32),
        "l_shipdate": ship,
        "l_commitdate": (odate + rng.integers(30, 91, n)).astype(np.int32),
        "l_receiptdate": (ship + rng.integers(1, 31, n)).astype(np.int32),
        "l_shipinstruct": rng.integers(0, 4, n).astype(np.int32),
        "l_shipmode": rng.integers(0, len(SHIPMODES), n).astype(np.int32),
    }
    if include_strings:
        cols["l_comment"] = _comments(rng, n)
    return Table(cols, lineitem_schema(include_strings))


def generate_tables(sf: float = 0.01, seed: int = 0,
                    include_strings: bool = True
                    ) -> tuple[Table, Table]:
    """In-memory generation (small SFs — tests and CI)."""
    rng = np.random.default_rng(seed)
    n_orders = max(1, int(ORDERS_ROWS_PER_SF * sf))
    orders = _gen_orders_chunk(rng, 1, n_orders, include_strings)
    lineitem = _gen_lineitem_chunk(rng, orders, include_strings)
    return lineitem, orders


def write_tpch(out_dir: str, sf: float, config: FileConfig, seed: int = 0,
               include_strings: bool = True, threads: int = 4,
               chunk_orders: int = 250_000
               ) -> dict[str, FileMeta]:
    """Streamed generation to ``out_dir/{lineitem,orders}.tab``."""
    os.makedirs(out_dir, exist_ok=True)
    rng = np.random.default_rng(seed)
    n_orders = max(1, int(ORDERS_ROWS_PER_SF * sf))

    lpath = os.path.join(out_dir, "lineitem.tab")
    opath = os.path.join(out_dir, "orders.tab")
    lw = TabFileWriter(lpath, config, threads).begin(
        lineitem_schema(include_strings))
    ow = TabFileWriter(opath, config, threads).begin(
        orders_schema(include_strings))

    def rg_stream(writer, tables_iter):
        pending, rows = [], 0
        for t in tables_iter:
            pending.append(t)
            rows += t.num_rows
            while rows >= config.rows_per_rg:
                buf = pending[0] if len(pending) == 1 else \
                    Table.concat(pending)
                writer.write_row_group(buf.slice(0, config.rows_per_rg))
                rest = buf.slice(config.rows_per_rg, buf.num_rows)
                pending = [rest] if rest.num_rows else []
                rows = rest.num_rows
        if rows:
            writer.write_row_group(pending[0] if len(pending) == 1
                                   else Table.concat(pending))

    lchunks, ochunks = [], []
    key = 1
    remaining = n_orders
    while remaining > 0:
        k = min(chunk_orders, remaining)
        oc = _gen_orders_chunk(rng, key, k, include_strings)
        ochunks.append(oc)
        lchunks.append(_gen_lineitem_chunk(rng, oc, include_strings))
        key += k
        remaining -= k
    rg_stream(ow, iter(ochunks))
    rg_stream(lw, iter(lchunks))
    ometa = ow.finish()
    lmeta = lw.finish()
    return {"lineitem": lmeta, "orders": ometa,
            "lineitem_path": lpath, "orders_path": opath}
