"""Tokenized LM corpus stored as TabFiles.

This is where the paper's technique becomes the training framework's input
pipeline: token streams live in columnar files whose configuration (page
count, RG size, FLEX encodings, selective compression) is exactly the
paper's study.  Token ids are zipf-distributed (dictionary/bit-pack
friendly, like real subword corpora) and carry a doc_id column
(delta-friendly) so the encoding-selection behavior is realistic.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.config import FileConfig
from repro.core.metadata import FileMeta
from repro.core.schema import Field, PhysicalType, Schema
from repro.core.table import Table
from repro.core.writer import TabFileWriter


def token_schema() -> Schema:
    return Schema([
        Field("token", PhysicalType.INT32),
        Field("doc_id", PhysicalType.INT32),
    ])


def generate_corpus(n_tokens: int, vocab_size: int, seed: int = 0,
                    mean_doc_len: int = 512) -> Table:
    rng = np.random.default_rng(seed)
    # zipf-ish over the vocab: heavy head like subword distributions
    z = rng.zipf(1.3, size=n_tokens)
    tokens = ((z - 1) % vocab_size).astype(np.int32)
    n_docs = max(1, n_tokens // mean_doc_len)
    doc_lens = rng.poisson(mean_doc_len, n_docs) + 1
    doc_id = np.repeat(np.arange(n_docs, dtype=np.int32), doc_lens)
    doc_id = doc_id[:n_tokens]
    if doc_id.shape[0] < n_tokens:
        doc_id = np.pad(doc_id, (0, n_tokens - doc_id.shape[0]),
                        constant_values=n_docs)
    return Table({"token": tokens, "doc_id": doc_id}, token_schema())


def write_corpus(path: str, n_tokens: int, vocab_size: int,
                 config: FileConfig, seed: int = 0,
                 threads: int = 2) -> FileMeta:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    writer = TabFileWriter(path, config, threads).begin(token_schema())
    chunk = 2_000_000
    written = 0
    while written < n_tokens:
        k = min(chunk, n_tokens - written)
        tbl = generate_corpus(k, vocab_size, seed=seed + written)
        for s in range(0, k, config.rows_per_rg):
            writer.write_row_group(tbl.slice(s, s + config.rows_per_rg))
        written += k
    return writer.finish()
