# Data substrate: synthetic TPC-H generator, token corpus, and the
# checkpointable training loader that streams batches through the paper's
# configured scan path.
