"""Checkpointable, sharded training loader over TabFile token corpora.

Determinism/resume contract: the global token stream is cut into fixed
records of (seq_len + 1) tokens; within an epoch, this shard's k-th record
is global record ``k * num_shards + shard_index``.  Loader state is a
single integer (records consumed by this shard), so restart resumes the
exact stream position; the cursor is stored in the checkpoint manifest.

I/O path: row groups stream through the paper's scan engine (host decode
backend for CPU throughput) with a small decoded-RG cache — consecutive
records of one shard stride across the stream, and million-row RGs
(Insight 2) keep the cache hit rate high.
"""

from __future__ import annotations

import collections
import dataclasses
import queue
import threading
from queue import Empty as _QueueEmpty, Full as _QueueFull
from collections.abc import Iterator

import numpy as np

from repro.core.scan import Scanner


@dataclasses.dataclass
class LoaderState:
    records_consumed: int = 0

    def to_json(self) -> dict:
        return {"records_consumed": self.records_consumed}

    @staticmethod
    def from_json(o: dict) -> "LoaderState":
        return LoaderState(records_consumed=o["records_consumed"])


class TabLoader:
    def __init__(self, path: str, seq_len: int, batch_per_shard: int,
                 shard_index: int = 0, num_shards: int = 1,
                 decode_backend: str = "host", rg_cache: int = 4):
        self.path = path
        self.seq_len = seq_len
        self.record_len = seq_len + 1
        self.batch_per_shard = batch_per_shard
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.scanner = Scanner(path, columns=["token"],
                               decode_backend=decode_backend)
        self.n_tokens = self.scanner.meta.num_rows
        self.records_per_epoch = self.n_tokens // self.record_len
        self.records_per_shard = max(1,
                                     self.records_per_epoch // num_shards)
        self.state = LoaderState()
        # RG index: starting token of each row group
        self._rg_starts = np.cumsum(
            [0] + [rg.n_rows for rg in self.scanner.meta.row_groups])
        self._cache: "collections.OrderedDict[int, np.ndarray]" = \
            collections.OrderedDict()
        self._cache_max = max(1, rg_cache)

    # -- state -------------------------------------------------------------

    def snapshot(self) -> LoaderState:
        return LoaderState(self.state.records_consumed)

    def restore(self, state: LoaderState) -> None:
        self.state = LoaderState(state.records_consumed)

    @property
    def epoch(self) -> int:
        return self.state.records_consumed // self.records_per_shard

    # -- token access ---------------------------------------------------------

    def _rg_tokens(self, rg_index: int) -> np.ndarray:
        hit = self._cache.get(rg_index)
        if hit is not None:
            self._cache.move_to_end(rg_index)
            return hit
        raws, _ = self.scanner.fetch_rg(rg_index)
        cols, _ = self.scanner.decode_rg(rg_index, raws)
        arr = np.asarray(cols["token"].array, dtype=np.int32)
        self._cache[rg_index] = arr
        while len(self._cache) > self._cache_max:
            self._cache.popitem(last=False)
        return arr

    def read_tokens(self, start: int, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.int32)
        pos = 0
        while pos < n:
            tok = start + pos
            rg = int(np.searchsorted(self._rg_starts, tok, "right")) - 1
            arr = self._rg_tokens(rg)
            lo = tok - int(self._rg_starts[rg])
            take = min(n - pos, arr.shape[0] - lo)
            out[pos:pos + take] = arr[lo:lo + take]
            pos += take
        return out

    # -- iteration ----------------------------------------------------------------

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        """(inputs, labels), each (batch_per_shard, seq_len) int32."""
        recs = []
        for _ in range(self.batch_per_shard):
            k = self.state.records_consumed % self.records_per_shard
            g = k * self.num_shards + self.shard_index
            g %= self.records_per_epoch
            recs.append(self.read_tokens(g * self.record_len,
                                         self.record_len))
            self.state.records_consumed += 1
        batch = np.stack(recs)
        return batch[:, :-1], batch[:, 1:]

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_batch()


class PrefetchLoader:
    """Background-thread prefetch: overlaps host I/O + decode with the
    accelerator step (the training-loop face of paper §4)."""

    def __init__(self, loader: TabLoader, depth: int = 2, device_put=None):
        self.loader = loader
        self.depth = depth
        self.device_put = device_put
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        batch = None
        while not self._stop.is_set():
            if batch is None:
                batch = self.loader.next_batch()
                if self.device_put is not None:
                    batch = tuple(self.device_put(x) for x in batch)
            try:
                self._q.put(batch, timeout=0.5)
                batch = None
            except _QueueFull:
                continue

    def __iter__(self):
        while not self._stop.is_set():
            try:
                yield self._q.get(timeout=5.0)
            except _QueueEmpty:
                continue

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except _QueueEmpty:
            pass
