"""End-to-end trainer driver.

Streams token batches out of a TabFile corpus through the paper's scan
path (Insights 1-4 live in the corpus file config) into any assigned
architecture.  ``--smoke`` trains the reduced config on CPU; full configs
are for real pods.

Example:
    python -m repro.launch.train --arch granite-3-8b --smoke --steps 200 \
        --corpus /tmp/corpus.tab --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.configs import get_arch, smoke_config
from repro.core.config import ACCELERATOR_OPTIMIZED
from repro.data.loader import TabLoader
from repro.data.tokens import write_corpus
from repro.models.model import Model
from repro.train.optimizer import OptConfig
from repro.train.runner import RunnerConfig, TrainRunner


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--corpus", default="/tmp/repro_corpus.tab")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate preemption at step N (FT demo)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_arch(
        args.arch).config
    model = Model(cfg)
    if not os.path.exists(args.corpus):
        n_tokens = max(2_000_000,
                       args.steps * args.batch * (args.seq_len + 1) * 2)
        print(f"writing corpus ({n_tokens:,} tokens) -> {args.corpus}")
        write_corpus(args.corpus, n_tokens, cfg.vocab_size,
                     ACCELERATOR_OPTIMIZED.replace(
                         rows_per_rg=1_000_000, target_pages_per_chunk=100),
                     seed=args.seed)
    loader = TabLoader(args.corpus, seq_len=args.seq_len,
                       batch_per_shard=args.batch)
    opt = OptConfig(peak_lr=args.lr, warmup_steps=max(5, args.steps // 20),
                    total_steps=args.steps)
    runner = TrainRunner(
        model, opt, loader, args.ckpt,
        RunnerConfig(total_steps=args.steps, save_every=args.save_every,
                     log_every=10, fail_at_step=args.fail_at),
        grad_accum=1, seed=args.seed)
    out = runner.run()
    print(f"done at step {out['final_step']}; "
          f"final loss {out['history'][-1]['loss']:.4f}"
          if out["history"] else "done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
