"""Roofline-term extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies **once**, but our
layer stacks are lax.scan loops — so FLOPs/bytes/collectives would be
undercounted by ~n_layers.  This module re-derives all three terms with
loop-trip multipliers:

  * computations are parsed, a call graph is built from while ops
    (``body=``/``condition=``), and each body's trip count is recovered
    from XLA's canonical `compare(iter, constant(N)), direction=LT`
    condition;
  * **collective bytes**: result-buffer size of every all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute;
  * **dot FLOPs** (the MXU roofline numerator): 2 · |result| · |contracted|
    per dot, operand shapes resolved through a per-computation symbol
    table;
  * **HBM bytes**: Σ (result + operands) of every top-level op except
    free ops (parameter/constant/tuple/get-tuple-element/bitcast); fusion
    computations are excluded (their traffic is the fusion op's operands
    and result at the call site — the fusion-semantics approximation of
    "bytes accessed").

If a trip count cannot be recovered the multiplier defaults to 1 and the
report is flagged ``exact_loop_multipliers=False`` (lower bound).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OP_LINE_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_WHILE_ATTR_RE = re.compile(
    r"condition=%?([\w.\-]+).*?body=%?([\w.\-]+)|"
    r"body=%?([\w.\-]+).*?condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_BC_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota", "while", "conditional", "call"}
# ops whose HBM traffic is NOT operands+result (in-place / view semantics):
#   dynamic-slice reads only the slice it produces;
#   dynamic-update-slice writes only the update region (in-place);
#   copy moves result bytes twice (read + write).
_SPECIAL_BYTES = {"dynamic-slice", "dynamic-update-slice", "copy"}


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                 # text after '(' (operands + attrs)
    is_root: bool = False


@dataclasses.dataclass
class HloReport:
    dot_flops: float
    memory_bytes: float
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]
    exact_loop_multipliers: bool
    n_computations: int

    @property
    def collective_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype,
                    [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> tuple[dict[str, list[Op]],
                                           str | None]:
    comps: dict[str, list[Op]] = {}
    cur: str | None = None
    entry: str | None = None
    ops: list[Op] = []
    hlo = _COMMENT_RE.sub("", hlo)
    for line in hlo.splitlines():
        s = line.strip()
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*"
                     r"(?:->\s*[^{]*)?\{$", s)
        if m:
            if cur is not None:
                comps[cur] = ops
            cur = m.group(2)
            if m.group(1):
                entry = cur
            ops = []
            continue
        if s == "}" or s == "})":
            if cur is not None:
                comps[cur] = ops
                cur = None
                ops = []
            continue
        if cur is None:
            continue
        om = _OP_LINE_RE.match(line)
        if om:
            ops.append(Op(om.group(2), om.group(3), om.group(4),
                          om.group(5), is_root=bool(om.group(1))))
    if cur is not None:
        comps[cur] = ops
    return comps, entry


def _trip_count(cond_ops: list[Op]) -> int | None:
    """Fallback when backend_config lacks known_trip_count: the canonical
    scan condition compares the counter against a constant bound."""
    consts: list[int] = []
    for op in cond_ops:
        if op.opcode == "constant":
            cm = re.match(r"^(\d+)\)", op.rest)
            if cm:
                consts.append(int(cm.group(1)))
        consts.extend(int(c) for c in _CONST_RE.findall(op.rest))
    return max(consts) if consts else None


_PARAM_IDX_RE = re.compile(r"^(\d+)\)")
_CALLSITE_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")


def _fusion_param_charges(fops: list[Op]) -> dict[int, int]:
    """Per-parameter byte charges for a fusion computation.

    A parameter consumed only by dynamic-slice ops is charged the sliced
    bytes; a parameter that is only the *target* buffer of a
    dynamic-update-slice is in-place (charged 0).  Everything else is
    charged its full size.
    """
    charges: dict[int, int] = {}
    params = {}
    for fop in fops:
        if fop.opcode == "parameter":
            m = _PARAM_IDX_RE.match(fop.rest)
            if m:
                params[fop.name] = (int(m.group(1)), fop.type_str)
    for pname, (idx, ptype) in params.items():
        uses = []
        for fop in fops:
            if fop.opcode == "parameter":
                continue
            refs = _OPERAND_RE.findall(fop.rest)
            if pname in refs:
                uses.append((fop, refs))
        if uses and all(u.opcode == "dynamic-slice" for u, _ in uses):
            charges[idx] = sum(_shape_bytes(u.type_str) for u, _ in uses)
        elif uses and all(u.opcode == "dynamic-update-slice"
                          and r and r[0] == pname for u, r in uses):
            charges[idx] = 0                       # in-place DUS target
        else:
            charges[idx] = _shape_bytes(ptype)
    return charges


def _fusion_bytes(op: Op, fops: list[Op], symbols: dict[str, str]) -> int:
    """Traffic of one fusion call site under slice-aware semantics."""
    charges = _fusion_param_charges(fops)
    fsymbols = {f.name: f.type_str for f in fops}
    result = _shape_bytes(op.type_str)
    root = next((f for f in fops if f.is_root), fops[-1] if fops else None)
    if root is not None and root.opcode == "dynamic-update-slice":
        refs = _OPERAND_RE.findall(root.rest)
        if len(refs) > 1 and refs[1] in fsymbols:
            result = _shape_bytes(fsymbols[refs[1]])   # write update only
    operand_part = op.rest.split(", kind=")[0].split(", calls=")[0]
    total = result
    for i, ref in enumerate(_OPERAND_RE.findall(operand_part)):
        t = symbols.get(ref)
        if t is None:
            continue
        total += charges.get(i, _shape_bytes(t))
    return total


def analyze_hlo(hlo: str) -> HloReport:
    comps, entry = _split_computations(hlo)
    exact = True

    # edges: parent -> (callee, multiplier_kind)
    sub_called = set()       # fusion/reducer computations: excluded
    loop_trips: dict[tuple[str, str], int] = {}
    cond_of: dict[tuple[str, str], str] = {}
    edges: dict[str, list[tuple[str, int]]] = {name: [] for name in comps}
    for parent, ops in comps.items():
        for op in ops:
            for m in _CALLS_RE.finditer(op.rest):
                sub_called.add(m.group(1))
            if op.opcode == "while":
                wm = _WHILE_ATTR_RE.search(op.rest)
                if not wm:
                    continue
                cond = wm.group(1) or wm.group(4)
                body = wm.group(2) or wm.group(3)
                bc = _TRIP_BC_RE.search(op.rest)
                trips = int(bc.group(1)) if bc else None
                if trips is None and cond in comps:
                    trips = _trip_count(comps[cond])
                if trips is None:
                    trips = 1
                    exact = False
                edges[parent].append((body, trips))
                edges[parent].append((cond, trips))
            elif op.opcode in ("call", "conditional"):
                for ref in _OPERAND_RE.finditer(op.rest):
                    if ref.group(1) in comps:
                        edges[parent].append((ref.group(1), 1))

    if entry is not None:
        roots = [entry]
    else:
        called = {c for es in edges.values() for c, _ in es} | sub_called
        roots = [c for c in comps if c not in called]
    mult: dict[str, int] = {}

    def visit(name: str, m: int):
        if name in mult and mult[name] >= m:
            return
        mult[name] = m
        for callee, k in edges.get(name, []):
            if callee not in sub_called:
                visit(callee, m * k)

    for r in roots:
        visit(r, 1)

    dot_flops = 0.0
    mem_bytes = 0.0
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}

    for name, ops in comps.items():
        m = mult.get(name, 0)
        if m == 0:
            continue
        symbols = {op.name: op.type_str for op in ops}

        for op in ops:
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                b = _shape_bytes(op.type_str)
                bytes_by[base] += b * m
                count_by[base] += m
            if op.opcode in _FREE_OPS:
                continue
            if op.opcode in _SPECIAL_BYTES:
                r = _shape_bytes(op.type_str)
                if op.opcode == "dynamic-slice":
                    b = 2 * r                       # read slice + write out
                elif op.opcode == "dynamic-update-slice":
                    refs = _OPERAND_RE.findall(op.rest)
                    upd = symbols.get(refs[1]) if len(refs) > 1 else None
                    b = 2 * (_shape_bytes(upd) if upd else r)
                else:                               # copy
                    b = 2 * r
                mem_bytes += b * m
            elif op.opcode == "fusion":
                cm = _CALLSITE_CALLS_RE.search(op.rest)
                fops = comps.get(cm.group(1), []) if cm else []
                mem_bytes += _fusion_bytes(op, fops, symbols) * m
            else:
                # result + named operands
                b = _shape_bytes(op.type_str)
                for ref in _OPERAND_RE.finditer(
                        op.rest.split(", calls=")[0]):
                    t = symbols.get(ref.group(1))
                    if t is not None:
                        b += _shape_bytes(t)
                mem_bytes += b * m
            # dot flops
            if op.opcode == "dot":
                refs = _OPERAND_RE.findall(op.rest)
                if refs:
                    lhs_t = symbols.get(refs[0])
                    cd = _LHS_CDIMS_RE.search(op.rest)
                    if lhs_t and cd is not None:
                        dims = _shape_dims(lhs_t)
                        if dims:
                            _, lhs_dims = dims[0]
                            contracted = 1
                            for i in (int(x) for x in
                                      cd.group(1).split(",") if x):
                                if i < len(lhs_dims):
                                    contracted *= lhs_dims[i]
                            result = 1
                            rdims = _shape_dims(op.type_str)
                            for d in (rdims[0][1] if rdims else []):
                                result *= d
                            dot_flops += 2.0 * result * contracted * m

    return HloReport(dot_flops=dot_flops, memory_bytes=mem_bytes,
                     bytes_by_kind=bytes_by, count_by_kind=count_by,
                     exact_loop_multipliers=exact,
                     n_computations=len(comps))


# Backwards-compatible wrapper used by dryrun.py
@dataclasses.dataclass
class CollectiveReport:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]
    exact_loop_multipliers: bool

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def analyze_collectives(hlo: str) -> CollectiveReport:
    r = analyze_hlo(hlo)
    return CollectiveReport(r.bytes_by_kind, r.count_by_kind,
                            r.exact_loop_multipliers)
