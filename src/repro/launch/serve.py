"""Batched serving driver (reduced configs on CPU; full configs on pods).

Example:
    python -m repro.launch.serve --arch mixtral-8x22b --smoke \
        --requests 16 --prompt-len 64 --max-new 32
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.models.model import Model
from repro.serve.engine import Request, ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_arch(
        args.arch).config
    if cfg.encoder_only:
        print(f"{args.arch} is encoder-only: no decode path to serve")
        return 1
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, max_batch=args.batch,
                         max_seq=args.prompt_len + args.max_new + 8,
                         temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(uid=i,
                    prompt=rng.integers(
                        0, cfg.vocab_size,
                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = engine.generate(reqs)
    wall = time.perf_counter() - t0
    rep = engine.throughput_report(done)
    print(f"served {rep['n_requests']} requests in {wall:.2f}s; "
          f"decode {rep['decode_tokens_per_s']:.1f} tok/s")
    sample = done[0].tokens[:16]
    print("sample completion tokens:", sample.tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
