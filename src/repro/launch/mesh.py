"""Production mesh builder.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets the 512-placeholder-device
XLA flag *before* any jax initialization.
"""

from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh(model_parallel: int = 1, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), axes,
                         axis_types=_auto(2))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_LINK_BW = 50e9              # B/s per link


def scan_devices(n: int | None = None) -> list:
    """Devices for the distributed scan executor (dataset/executor.py).

    ``None`` → every jax device.  ``n`` → the first n devices, cycling
    when n exceeds what the platform exposes (so devices=4 still runs —
    and still reduces deterministically — on a 1-device host; real
    speedup needs real devices or XLA_FLAGS host-platform emulation).
    """
    devs = list(jax.devices())
    if n is None:
        return devs
    n = max(1, int(n))
    return [devs[i % len(devs)] for i in range(n)]
