import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices build the production meshes, every
cell's step function is lowered with ShapeDtypeStruct inputs (no
allocation) and compiled through the full XLA SPMD partitioner, and the
compiled artifact yields memory_analysis() (fits?), cost_analysis()
(FLOPs/bytes) and the parsed collective bytes for §Roofline.

Usage:
    python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both [--jobs 2]
    python -m repro.launch.dryrun --list
"""

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import (ARCHS, SHAPES, applicable_shapes, get_arch,  # noqa: E402
                           input_specs, skip_reason)
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.models.model import Model  # noqa: E402
from repro.parallel.sharding import param_pspecs  # noqa: E402
from repro.train.optimizer import OptConfig  # noqa: E402
from repro.train.step import (abstract_train_state, build_train_step,  # noqa: E402
                              state_shardings)

DEFAULT_OUT = "results/dryrun"


# ---------------------------------------------------------------------------
# cache shardings (path-aware, divisibility-checked)
# ---------------------------------------------------------------------------

def _dp_axes(mesh_axes):
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def cache_shardings(caches_abs, mesh):
    """Leaves carry a leading (steps,) scan axis; never sharded.

    k/v     (L,B,S,KV,dh): KV on model if divisible, else S (flash-decoding
            style cache-length sharding), else replicated
    ckv     (L,B,S,r):  S on model (length-sharded latents)
    krope   (L,B,S,dr): S on model
    conv    (L,B,K,C):  C on model
    ssd     (L,B,H,P,N): H on model
    pos     (L,W): replicated
    """
    axes = tuple(mesh.axis_names)
    dp = _dp_axes(axes)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tp = mesh.shape["model"] if "model" in axes else 1

    def leaf_spec(path: str, shape) -> P:
        name = path.split("/")[-1]
        dims = list(shape)
        if name == "pos" or len(dims) < 3:
            return P()
        spec = [None] * len(dims)
        if dims[1] % dp_size == 0 and dims[1] >= dp_size:
            spec[1] = dp
        if name in ("k", "v", "k_scale", "v_scale") and len(dims) == 5:
            if tp > 1 and dims[3] % tp == 0:
                spec[3] = "model"
            elif tp > 1 and dims[2] % tp == 0:
                spec[2] = "model"
        elif name in ("ckv", "krope") and len(dims) == 4:
            if tp > 1 and dims[2] % tp == 0:
                spec[2] = "model"
        elif name == "conv" and len(dims) == 4:
            if tp > 1 and dims[3] % tp == 0:
                spec[3] = "model"
        elif name == "ssd" and len(dims) == 5:
            if tp > 1 and dims[2] % tp == 0:
                spec[2] = "model"
        return P(*spec)

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
        if node is None:
            return None
        return NamedSharding(mesh, leaf_spec(path, node.shape))

    return walk(caches_abs, "")


def batch_sharding_tree(batch_abs, mesh):
    axes = tuple(mesh.axis_names)
    dp = _dp_axes(axes)

    def leaf(x):
        spec = [None] * len(x.shape)
        if x.shape and x.shape[0] % max(
                1, _prod(mesh.shape[a] for a in dp)) == 0 and dp:
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, batch_abs)


def _prod(it):
    out = 1
    for x in it:
        out *= x
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               opt_overrides: dict | None = None,
               cfg_overrides: dict | None = None,
               arch_overrides: dict | None = None):
    arch = get_arch(arch_name)
    if arch_overrides:
        arch = dataclasses.replace(arch, **arch_overrides)
    cfg = arch.config
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    axes = tuple(mesh.axis_names)

    opt_kw = {"moments_dtype": "float32"}
    opt_kw.update(opt_overrides or {})
    opt_cfg = OptConfig(**opt_kw)

    with jax.set_mesh(mesh):
        batch_abs = input_specs(arch, shape_name)
        params_abs = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        pspecs = param_pspecs(params_abs, zero=arch.zero, mesh_axes=axes,
                              mesh_sizes=sizes)
        params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
        if shape.kind == "train":
            step = build_train_step(model, opt_cfg, arch.grad_accum)
            state_abs = abstract_train_state(model, opt_cfg)
            state_sh = state_shardings(state_abs, mesh, arch.zero)
            batch_sh = batch_sharding_tree(batch_abs, mesh)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            if cfg.encoder_only:
                jitted = jax.jit(
                    model.encode,
                    in_shardings=(params_sh,
                                  batch_sharding_tree(batch_abs, mesh)),
                    out_shardings=None)
                lowered = jitted.lower(params_abs, batch_abs)
            else:
                caches_abs = jax.eval_shape(
                    lambda: model.init_caches(shape.global_batch,
                                              shape.seq_len))
                caches_sh = cache_shardings(caches_abs, mesh)
                jitted = jax.jit(
                    model.prefill,
                    in_shardings=(params_sh,
                                  batch_sharding_tree(batch_abs, mesh),
                                  caches_sh),
                    out_shardings=(None, caches_sh))
                lowered = jitted.lower(params_abs, batch_abs, caches_abs)
        else:  # decode
            caches_abs = jax.eval_shape(
                lambda: model.init_caches(shape.global_batch,
                                          shape.seq_len))
            caches_sh = cache_shardings(caches_abs, mesh)
            token_sh = batch_sharding_tree(
                {"token": batch_abs["token"]}, mesh)["token"]
            jitted = jax.jit(
                model.decode_step,
                in_shardings=(params_sh, token_sh,
                              NamedSharding(mesh, P()), caches_sh),
                out_shardings=(None, caches_sh))
            lowered = jitted.lower(params_abs, batch_abs["token"],
                                   batch_abs["pos"], caches_abs)
    return lowered, mesh, arch, cfg


def _mem_number(mem, name: str):
    v = getattr(mem, name, None)
    try:
        return int(v) if v is not None else None
    except Exception:
        return None


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str = DEFAULT_OUT, collect_hlo: bool = True,
             opt_overrides=None, cfg_overrides=None,
             variant: str = "baseline",
             arch_overrides: dict | None = None) -> dict:
    t0 = time.time()
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    arch = get_arch(arch_name)
    if arch_overrides:
        arch = dataclasses.replace(arch, **arch_overrides)
    reason = skip_reason(arch, shape_name)
    rec: dict = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_name,
        "variant": variant,
    }
    if reason:
        rec.update({"status": "skipped", "reason": reason})
        _write(rec, out_dir)
        return rec

    lowered, mesh, arch, cfg = lower_cell(arch_name, shape_name, multi_pod,
                                          opt_overrides, cfg_overrides,
                                          arch_overrides)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    bytes_accessed = float(cost.get("bytes accessed", 0.0)) if cost else 0.0

    hrep = None
    if collect_hlo:
        try:
            hlo = compiled.as_text()
            hrep = analyze_hlo(hlo)
        except Exception as e:  # keep the cell result even if parsing dies
            rec["collective_error"] = repr(e)

    n_dev = mesh.devices.size
    rec.update({
        "status": "ok",
        "n_devices": int(n_dev),
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "model_params": arch.config.param_count(),
        "model_params_active": arch.config.active_param_count(),
        "grad_accum": arch.grad_accum,
        "zero": arch.zero,
        "memory": {
            "argument_bytes": _mem_number(mem, "argument_size_in_bytes"),
            "output_bytes": _mem_number(mem, "output_size_in_bytes"),
            "temp_bytes": _mem_number(mem, "temp_size_in_bytes"),
            "code_bytes": _mem_number(mem, "generated_code_size_in_bytes"),
        },
    })
    if hrep is not None:
        rec["hlo"] = {
            "dot_flops_per_device": hrep.dot_flops,
            "memory_bytes_per_device": hrep.memory_bytes,
            "n_computations": hrep.n_computations,
            "exact_loop_multipliers": hrep.exact_loop_multipliers,
        }
        rec["collectives"] = {
            "bytes_by_kind": hrep.bytes_by_kind,
            "count_by_kind": hrep.count_by_kind,
            "total_bytes": hrep.collective_bytes,
        }
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str) -> None:
    d = os.path.join(out_dir, rec["mesh"])
    os.makedirs(d, exist_ok=True)
    suffix = "" if rec.get("variant", "baseline") == "baseline" \
        else f"__{rec['variant']}"
    path = os.path.join(
        d, f"{rec['arch']}__{rec['shape']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def all_cells():
    for a in ARCHS:
        arch = get_arch(a)
        for s in SHAPES:
            yield a, s, s in applicable_shapes(arch)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip collective parsing (faster)")
    # §Perf variant knobs
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--remat", choices=["full", "dots", "none"])
    ap.add_argument("--moments", choices=["float32", "bfloat16"])
    ap.add_argument("--accum", type=int, help="grad accumulation override")
    ap.add_argument("--preferred-accum", action="store_true",
                    help="bf16 matmul inputs + f32 accumulation")
    ap.add_argument("--no-zero", action="store_true",
                    help="disable FSDP param sharding")
    ap.add_argument("--moe-shmap", action="store_true",
                    help="explicit shard_map MoE (psum combine)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache with per-(token,head) scales")
    args = ap.parse_args()

    cfg_overrides = {}
    if args.remat:
        cfg_overrides["remat"] = args.remat
    if args.preferred_accum:
        cfg_overrides["accum_via_preferred"] = True
    if args.moe_shmap:
        cfg_overrides["moe_shmap"] = True
    if args.kv_int8:
        cfg_overrides["kv_cache_dtype"] = "int8"
    opt_overrides = {}
    if args.moments:
        opt_overrides["moments_dtype"] = args.moments
    arch_overrides = {}
    if args.accum is not None:
        arch_overrides["grad_accum"] = args.accum
    if args.no_zero:
        arch_overrides["zero"] = False

    if args.list:
        for a, s, ok in all_cells():
            arch = get_arch(a)
            note = "" if ok else f"  SKIP: {skip_reason(arch, s)}"
            print(f"{a:22s} {s:12s}{note}")
        return 0

    meshes = (["single_pod", "multi_pod"] if args.mesh == "both"
              else [args.mesh])

    if args.all:
        cells = [(a, s, m) for a, s, _ in all_cells() for m in meshes]
        procs = []
        failures = []
        for a, s, m in cells:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m,
                   "--out", args.out] + (["--no-hlo"] if args.no_hlo
                                         else [])
            procs.append((a, s, m, subprocess.Popen(cmd)))
            while len([p for *_, p in procs if p.poll() is None]) \
                    >= args.jobs:
                time.sleep(1.0)
        for a, s, m, p in procs:
            if p.wait() != 0:
                failures.append((a, s, m))
        if failures:
            print("FAILED CELLS:", failures)
            return 1
        print(f"all {len(cells)} cells OK")
        return 0

    assert args.arch and args.shape
    for m in meshes:
        rec = run_cell(args.arch, args.shape, m == "multi_pod",
                       out_dir=args.out, collect_hlo=not args.no_hlo,
                       opt_overrides=opt_overrides or None,
                       cfg_overrides=cfg_overrides or None,
                       arch_overrides=arch_overrides or None,
                       variant=args.variant)
        status = rec["status"]
        if status == "ok":
            print(f"{args.arch} {args.shape} {m}: compiled "
                  f"lower={rec['lower_seconds']}s "
                  f"compile={rec['compile_seconds']}s "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"coll={rec.get('collectives', {}).get('total_bytes', 'n/a')}")
        else:
            print(f"{args.arch} {args.shape} {m}: SKIP ({rec['reason']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
