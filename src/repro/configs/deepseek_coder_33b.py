"""DeepSeek-Coder-33B — llama-arch dense [arXiv:2401.14196; hf].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="deepseek-coder-33b", family="dense",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=19200, vocab_size=32_256,
        block_pattern=("full",), act="silu",
    ),
    long_context_ok=False,
    zero=True,
    grad_accum=8,
    source="arXiv:2401.14196; hf",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=192, vocab_size=503, param_dtype="float32",
        compute_dtype="float32", loss_chunk=64)
