"""DeepSeek-V3 671B — MLA + fine-grained MoE + MTP [arXiv:2412.19437; hf].

61L d_model=7168 128H, MLA (q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128); MoE: 3 leading dense layers (d_ff 18432), then 1 shared + 256
routed experts (d_expert 2048) top-8; vocab 129280; MTP head (1 extra
block).  GQA kv=128 in the brief ⇒ MHA head count under MLA.
"""

from repro.configs.base import ArchSpec
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_head=192,
        d_ff=2048, vocab_size=129_280,
        block_pattern=("full",), act="silu",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                      first_dense=3, dense_d_ff=18432,
                      capacity_factor=1.25),
        mtp=True,
    ),
    long_context_ok=False,   # MLA attends over the full (compressed) cache
    zero=True,
    grad_accum=8,
    source="arXiv:2412.19437; hf",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_head=48, vocab_size=512, d_ff=64,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=32,
                      qk_rope_dim=16, v_head_dim=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, n_shared=1,
                      first_dense=1, dense_d_ff=256),
        param_dtype="float32", compute_dtype="float32", loss_chunk=64)
