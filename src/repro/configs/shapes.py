"""The assigned input shapes and the applicability rules (DESIGN.md §4)."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(arch) -> list[str]:
    """Skip rules: encoder-only archs have no decode step; long_500k needs
    sub-quadratic attention (SSM / window-only / hybrid-with-window)."""
    names = []
    for name, sh in SHAPES.items():
        if sh.kind == "decode" and arch.config.encoder_only:
            continue
        if name == "long_500k" and not arch.long_context_ok:
            continue
        names.append(name)
    return names


def skip_reason(arch, shape_name: str) -> str:
    sh = SHAPES[shape_name]
    if sh.kind == "decode" and arch.config.encoder_only:
        return "encoder-only: no decode step"
    if shape_name == "long_500k" and not arch.long_context_ok:
        return "full attention is quadratic at 500k; no sub-quadratic path"
    return ""
