"""Gemma2-2B — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000; window 4096;
attn softcap 50, final softcap 30; GeGLU; sandwich norms; scaled embeds.
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="gemma2-2b", family="dense",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_head=256,
        d_ff=9216, vocab_size=256_000,
        block_pattern=("window", "full"), window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        act="gelu", use_post_norm=True, embed_scale=True,
    ),
    long_context_ok=False,   # alternating layers include *global* attention
    zero=True,               # 256k vocab embedding
    grad_accum=2,
    source="arXiv:2408.00118; hf",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_head=32, d_ff=256, vocab_size=512, window=16,
        param_dtype="float32", compute_dtype="float32", loss_chunk=64)
