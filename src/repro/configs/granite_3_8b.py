"""Granite-3 8B — GQA dense [hf:ibm-granite/granite-3.0-2b-base; hf].

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="granite-3-8b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12800, vocab_size=49_155,
        block_pattern=("full",), act="silu",
    ),
    long_context_ok=False,
    zero=True,
    grad_accum=4,
    source="hf:ibm-granite/granite-3.0-2b-base",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab_size=499, param_dtype="float32",
        compute_dtype="float32", loss_chunk=64)
