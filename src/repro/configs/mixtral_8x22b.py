"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff(expert)=16384 vocab=32768; SWA per
the brief ⇒ window 4096 on every layer, which bounds the KV cache and
makes long_500k runnable.
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig, MoEConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="mixtral-8x22b", family="moe",
        n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32_768,
        block_pattern=("window",), window=4096, act="silu",
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=16384,
                      capacity_factor=1.25),
    ),
    long_context_ok=True,    # SWA: cache bounded at window
    zero=True,
    grad_accum=8,
    source="arXiv:2401.04088; hf",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128),
        param_dtype="float32", compute_dtype="float32", loss_chunk=64)
