"""Zamba2-7B — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242; unverified].

81 Mamba2 layers (d_model=3584, ssm_state=64) with one weight-shared
full-attention+MLP block (32H MHA, d_ff 14336) applied every 6 SSM layers.
long_500k runs: SSM state is O(1) and the shared-attn cache is windowed at
serve time (DESIGN.md §4).
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig, SSMConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32_000,
        block_pattern=("ssm",), shared_attn_every=6,
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, d_conv=4,
                      chunk=256),
        act="gelu",
    ),
    long_context_ok=True,
    zero=True,
    grad_accum=4,
    source="arXiv:2411.15242; unverified",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=5, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512, shared_attn_every=2,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=32, d_conv=4,
                      chunk=32),
        param_dtype="float32", compute_dtype="float32", loss_chunk=64)
