"""HuBERT-XLarge — encoder-only audio transformer [arXiv:2106.07447;
unverified].

48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (cluster units).
Encoder-only: bidirectional attention, masked-unit-prediction loss, no
decode step (decode shapes skipped).  The conv feature extractor is a STUB:
input_specs() provides precomputed frame embeddings (B, S, d_model).
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="hubert-xlarge", family="audio",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
        d_ff=5120, vocab_size=504,
        block_pattern=("full",), act="gelu",
        encoder_only=True, frontend="audio",
    ),
    long_context_ok=False,
    zero=False,
    grad_accum=1,
    source="arXiv:2106.07447; unverified",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=97, param_dtype="float32",
        compute_dtype="float32", loss_chunk=64)
