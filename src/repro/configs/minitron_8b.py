"""Minitron-8B — pruned Nemotron [arXiv:2407.14679; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=16384, vocab_size=256_000,
        block_pattern=("full",), act="silu",
    ),
    long_context_ok=False,   # full attention — long_500k skipped
    zero=True,               # 256k vocab + 8B params: shard over data too
    grad_accum=4,
    source="arXiv:2407.14679; hf",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab_size=512, param_dtype="float32",
        compute_dtype="float32", loss_chunk=64)
