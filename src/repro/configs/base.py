"""Architecture registry: full configs, reduced smoke configs, input specs."""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import SHAPES, Shape
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    long_context_ok: bool          # sub-quadratic path exists for 500k
    zero: bool = False             # FSDP params+optimizer over data axis
    grad_accum: int = 1            # microbatch accumulation for train_4k
    notes: str = ""
    source: str = ""               # provenance tag from the brief

    @property
    def name(self) -> str:
        return self.config.name


_ARCH_MODULES = {
    "minitron-8b": "minitron_8b",
    "granite-3-8b": "granite_3_8b",
    "gemma2-2b": "gemma2_2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "internvl2-76b": "internvl2_76b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-2.7b": "mamba2_2p7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mixtral-8x22b": "mixtral_8x22b",
    "zamba2-7b": "zamba2_7b",
}

ARCHS = tuple(_ARCH_MODULES)


def get_arch(name: str) -> ArchSpec:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.ARCH


def smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.smoke()


def list_archs():
    return [get_arch(n) for n in ARCHS]


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation; dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(arch: ArchSpec, shape_name: str,
                batch_override: int | None = None) -> dict:
    """Returns the abstract inputs for the given cell.

    train:   {"tokens","labels"} (+frontend extras)
    prefill: {"tokens"} (+frontend extras)
    decode:  {"token" (B,1), "pos" ()} — caches are built separately
    """
    cfg = arch.config
    sh: Shape = SHAPES[shape_name]
    b = batch_override if batch_override is not None else sh.global_batch
    s = sh.seq_len
    i32 = jnp.int32
    cdt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[
        cfg.compute_dtype]

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    if sh.kind == "decode":
        return {"token": tok((b, 1)), "pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.frontend == "audio":
        specs = {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt),
                 "labels": tok((b, s)),
                 "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_)}
        if sh.kind == "prefill":
            specs.pop("labels")
            specs.pop("mask")
        return specs
    if cfg.frontend == "vision":
        n_img = cfg.n_frontend_tokens
        s_text = s - n_img
        specs = {"tokens": tok((b, s_text)),
                 "img_embeds": jax.ShapeDtypeStruct((b, n_img, cfg.d_model),
                                                    cdt)}
        if sh.kind == "train":
            specs["labels"] = tok((b, s_text))
        return specs
    specs = {"tokens": tok((b, s))}
    if sh.kind == "train":
        specs["labels"] = tok((b, s))
    return specs


def concrete_inputs(arch: ArchSpec, shape_name: str, batch: int,
                    seq_len: int | None = None, seed: int = 0) -> dict:
    """Small concrete batches for smoke tests (reduced configs only)."""
    cfg = arch.config
    sh = SHAPES[shape_name]
    rng = np.random.default_rng(seed)
    s = seq_len if seq_len is not None else sh.seq_len
    v = cfg.vocab_size

    if sh.kind == "decode":
        return {"token": jnp.asarray(rng.integers(0, v, (batch, 1)),
                                     jnp.int32),
                "pos": jnp.asarray(0, jnp.int32)}
    if cfg.frontend == "audio":
        out = {"frames": jnp.asarray(
            rng.normal(size=(batch, s, cfg.d_model)), jnp.float32)}
        if sh.kind == "train":
            out["labels"] = jnp.asarray(rng.integers(0, v, (batch, s)),
                                        jnp.int32)
            out["mask"] = jnp.asarray(rng.random((batch, s)) < 0.3)
        return out
    if cfg.frontend == "vision":
        n_img = cfg.n_frontend_tokens
        st = s - n_img
        out = {"tokens": jnp.asarray(rng.integers(0, v, (batch, st)),
                                     jnp.int32),
               "img_embeds": jnp.asarray(
                   rng.normal(size=(batch, n_img, cfg.d_model)),
                   jnp.float32)}
        if sh.kind == "train":
            out["labels"] = jnp.asarray(rng.integers(0, v, (batch, st)),
                                        jnp.int32)
        return out
    out = {"tokens": jnp.asarray(rng.integers(0, v, (batch, s)), jnp.int32)}
    if sh.kind == "train":
        out["labels"] = jnp.asarray(rng.integers(0, v, (batch, s)),
                                    jnp.int32)
    return out
