"""InternVL2-76B — InternViT + (Llama3-70B-class) LLM backbone
[arXiv:2404.16821; unverified].

Backbone only per the brief: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  The InternViT frontend is a STUB: input_specs() provides
precomputed patch embeddings (n_frontend_tokens per sample) that the model
prepends to the text embedding stream.
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="internvl2-76b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab_size=128_256,
        block_pattern=("full",), act="silu",
        frontend="vision", n_frontend_tokens=256,
    ),
    long_context_ok=False,
    zero=True,
    grad_accum=8,
    source="arXiv:2404.16821; unverified",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512, n_frontend_tokens=8,
        param_dtype="float32", compute_dtype="float32", loss_chunk=64)
