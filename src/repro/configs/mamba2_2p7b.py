"""Mamba2-2.7B — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified].

64L d_model=2560 d_ff=0 vocab=50280, ssm_state=128, headdim=64, expand=2.
All shapes including long_500k (O(1) state decode).
"""

from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig, SSMConfig

ARCH = ArchSpec(
    config=ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50_280,
        block_pattern=("ssm",),
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, d_conv=4,
                      chunk=256),
    ),
    long_context_ok=True,
    zero=False,
    grad_accum=2,
    source="arXiv:2405.21060; unverified",
)


def smoke() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        ARCH.config, n_layers=2, d_model=128, vocab_size=512,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=32, d_conv=4,
                      chunk=32),
        param_dtype="float32", compute_dtype="float32", loss_chunk=64)
