from repro.configs.base import (ARCHS, ArchSpec, concrete_inputs, get_arch,
                                input_specs, list_archs, smoke_config)
from repro.configs.shapes import SHAPES, Shape, applicable_shapes, skip_reason

__all__ = ["ARCHS", "ArchSpec", "concrete_inputs", "get_arch",
           "input_specs", "list_archs", "smoke_config", "SHAPES", "Shape",
           "applicable_shapes", "skip_reason"]
