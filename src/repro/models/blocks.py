"""Layer composition: blocks → scanned segments → full stacks.

Layers are grouped into *segments* of repeating structure (a segment step =
one period of the block pattern) and executed with lax.scan over stacked
parameters — one period of HLO per segment regardless of depth, which keeps
the 512-device dry-run compile tractable for 62–81-layer archs.

Segment examples:
  dense-40L         [Segment(kinds=("full",), ffn="dense", steps=40)]
  gemma2-26L        [Segment(kinds=("window","full"), ffn="dense", steps=13)]
  deepseek-v3-61L   [Segment(("full",),"dense",3), Segment(("full",),"moe",58)]
  zamba2-81L        [Segment(("ssm",)*6,"none",13,shared_attn=True),
                     Segment(("ssm",)*3,"none",1,shared_attn=True)]
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import dtype_of, mlp, rms_norm
from repro.parallel.sharding import constrain


@dataclasses.dataclass(frozen=True)
class Segment:
    kinds: tuple[str, ...]       # per-position within one step
    ffn: str                     # "dense" | "moe" | "none"
    steps: int
    shared_attn: bool = False    # apply the weight-shared attn block first
    d_ff: int = 0                # dense ffn width for this segment


def layer_plan(cfg: ModelConfig) -> list[Segment]:
    if cfg.shared_attn_every:
        per = cfg.shared_attn_every
        full_steps = cfg.n_layers // per
        rem = cfg.n_layers - full_steps * per
        segs = [Segment(("ssm",) * per, "none", full_steps,
                        shared_attn=True)]
        if rem:
            segs.append(Segment(("ssm",) * rem, "none", 1,
                                shared_attn=True))
        return segs
    period = len(cfg.block_pattern)
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)
    steps = cfg.n_layers // period
    if cfg.moe is not None:
        fd = cfg.moe.first_dense
        assert period == 1, "MoE with multi-kind patterns unsupported"
        segs = []
        if fd:
            segs.append(Segment(cfg.block_pattern, "dense", fd,
                                d_ff=cfg.moe.dense_d_ff or cfg.d_ff))
        segs.append(Segment(cfg.block_pattern, "moe", steps - fd))
        return segs
    ffn = "none" if cfg.d_ff == 0 else "dense"
    return [Segment(cfg.block_pattern, ffn, steps, d_ff=cfg.d_ff)]


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def init_block_params(rng, cfg: ModelConfig, kind: str, ffn: str,
                      d_ff: int) -> dict:
    dtype = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    keys = jax.random.split(rng, 4)
    p: dict = {"norm1": jnp.zeros((d,), dtype)}
    if kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm_params(keys[0], cfg, dtype)
    elif cfg.mla is not None:
        p["attn"] = mla_mod.init_mla_params(keys[0], cfg, dtype)
    else:
        p["attn"] = attn.init_attn_params(keys[0], cfg, dtype)
    if cfg.use_post_norm:
        p["post_norm1"] = jnp.zeros((d,), dtype)
    if ffn == "dense":
        s = d ** -0.5
        p["norm2"] = jnp.zeros((d,), dtype)
        p["mlp"] = {
            "w1": (jax.random.normal(keys[1], (d, d_ff)) * s).astype(dtype),
            "w3": (jax.random.normal(keys[2], (d, d_ff)) * s).astype(dtype),
            "w2": (jax.random.normal(keys[3], (d_ff, d))
                   * d_ff ** -0.5).astype(dtype),
        }
        if cfg.use_post_norm:
            p["post_norm2"] = jnp.zeros((d,), dtype)
    elif ffn == "moe":
        p["norm2"] = jnp.zeros((d,), dtype)
        p["moe"] = moe_mod.init_moe_params(keys[1], cfg, dtype)
        if cfg.use_post_norm:
            p["post_norm2"] = jnp.zeros((d,), dtype)
    return p


def block_forward(bp: dict, x: jnp.ndarray, cfg: ModelConfig, kind: str,
                  ffn: str, positions: jnp.ndarray, *,
                  mode: str = "train", cache: dict | None = None,
                  pos: jnp.ndarray | None = None,
                  bidirectional: bool = False,
                  window_override: int | None = None
                  ) -> tuple[jnp.ndarray, dict | None, jnp.ndarray]:
    """One block. Returns (x, new_cache_or_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, bp["norm1"])
    window = cfg.window if kind == "window" else 0
    if window_override is not None:
        window = window_override
    new_cache = None
    if kind == "ssm":
        if mode == "decode":
            mix, new_cache = ssm_mod.ssm_decode(bp["ssm"], h, cache, cfg)
        else:
            mix, new_cache = ssm_mod.ssm_forward(
                bp["ssm"], h, cfg, state=None,
                return_state=(mode == "prefill"))
    elif cfg.mla is not None:
        if mode == "train":
            mix = mla_mod.mla_train(bp["attn"], h, positions, cfg)
        elif mode == "prefill":
            mix, new_cache = mla_mod.mla_prefill(bp["attn"], h, positions,
                                                 cfg, cache)
        else:
            mix, new_cache = mla_mod.mla_decode(bp["attn"], h, pos, cache,
                                                cfg)
    else:
        if mode == "train":
            mix = attn.attn_train(bp["attn"], h, positions, cfg,
                                  window=window,
                                  bidirectional=bidirectional)
        elif mode == "prefill":
            mix, new_cache = attn.attn_prefill(bp["attn"], h, positions,
                                               cfg, window=window,
                                               cache=cache)
        else:
            mix, new_cache = attn.attn_decode(bp["attn"], h, pos, cache,
                                              cfg, window=window)
    if cfg.use_post_norm:
        mix = rms_norm(mix, bp["post_norm1"])
    x = x + mix
    if ffn == "dense":
        h2 = rms_norm(x, bp["norm2"])
        out = mlp(h2, bp["mlp"]["w1"], bp["mlp"]["w3"], bp["mlp"]["w2"],
                  cfg.act)
        if cfg.use_post_norm:
            out = rms_norm(out, bp["post_norm2"])
        x = x + out
    elif ffn == "moe":
        h2 = rms_norm(x, bp["norm2"])
        out, aux = moe_mod.moe_forward(bp["moe"], h2, cfg)
        if cfg.use_post_norm:
            out = rms_norm(out, bp["post_norm2"])
        x = x + out
    x = constrain(x, "batch", None, None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# cache initializers
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ModelConfig, kind: str, b: int, s_max: int,
                     window_override: int | None = None):
    dtype = dtype_of(cfg.compute_dtype)
    if kind == "ssm":
        return ssm_mod.init_ssm_state(b, cfg, dtype)
    if cfg.mla is not None:
        return mla_mod.init_mla_cache(b, s_max, cfg, dtype)
    window = cfg.window if kind == "window" else 0
    if window_override is not None:
        window = window_override
    if window and window < s_max:
        return attn.init_window_cache(b, window, cfg, dtype)
    return attn.init_full_cache(b, s_max, cfg, dtype)


# ---------------------------------------------------------------------------
# segments (scanned stacks)
# ---------------------------------------------------------------------------

def init_segment_params(rng, cfg: ModelConfig, seg: Segment) -> dict:
    """Stacked params: each leaf gains a leading (steps,) axis."""
    def one_step(r):
        ks = jax.random.split(r, len(seg.kinds))
        return {f"pos{i}": init_block_params(ks[i], cfg, kind, seg.ffn,
                                             seg.d_ff)
                for i, kind in enumerate(seg.kinds)}

    rngs = jax.random.split(rng, seg.steps)
    per_step = [one_step(r) for r in rngs]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_step)


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def segment_forward(sp: dict, x: jnp.ndarray, cfg: ModelConfig,
                    seg: Segment, positions: jnp.ndarray, *,
                    mode: str = "train", caches=None,
                    pos: jnp.ndarray | None = None,
                    shared_params: dict | None = None,
                    shared_caches=None, bidirectional: bool = False,
                    shared_window: int | None = None):
    """Scan over the segment's steps.

    caches / shared_caches carry a leading (steps,) axis; the scan emits the
    updated stacks.  Returns (x, new_caches, new_shared_caches, aux_sum).
    """

    def step_fn(carry, xs):
        xc, aux = carry
        step_params, step_cache, shared_cache = xs
        if seg.shared_attn and shared_params is not None:
            xc, new_shared, a0 = block_forward(
                shared_params, xc, cfg, "full", "dense", positions,
                mode=mode, cache=shared_cache, pos=pos,
                bidirectional=bidirectional, window_override=shared_window)
            aux = aux + a0
        else:
            new_shared = shared_cache
        new_step_cache = []
        for i, kind in enumerate(seg.kinds):
            bp = step_params[f"pos{i}"]
            c = None if step_cache is None else step_cache[f"pos{i}"]
            xc, nc, a = block_forward(bp, xc, cfg, kind, seg.ffn, positions,
                                      mode=mode, cache=c, pos=pos,
                                      bidirectional=bidirectional)
            aux = aux + a
            new_step_cache.append(nc)
        out_cache = (None if step_cache is None else
                     {f"pos{i}": c for i, c in enumerate(new_step_cache)})
        return (xc, aux), (out_cache, new_shared)

    body = _remat_wrap(step_fn, cfg) if mode == "train" else step_fn
    aux0 = jnp.zeros((), jnp.float32)
    xs = (sp, caches, shared_caches)
    if caches is None and shared_caches is None:
        # scan requires concrete xs; wrap Nones as per-step dummies
        xs = (sp, jnp.zeros((seg.steps,), jnp.int8),
              jnp.zeros((seg.steps,), jnp.int8))

        def body2(carry, z):
            step_params, _, _ = z
            return body(carry, (step_params, None, None))[0], None

        (x, aux), _ = jax.lax.scan(body2, (x, aux0), xs)
        return x, None, None, aux
    (x, aux), (new_caches, new_shared) = jax.lax.scan(body, (x, aux0), xs)
    return x, new_caches, new_shared, aux
