# Unified model family covering the ten assigned architectures:
# dense GQA transformers (full/windowed/alternating attention, softcaps),
# MLA, MoE (top-k + shared experts), Mamba2 SSD, hybrid (Zamba2), and
# VLM/audio stub frontends.  Scan-over-layers keeps HLO compact for the
# 512-device dry-run.
