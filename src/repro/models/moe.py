"""Mixture-of-Experts layer: top-k routing, capacity-based sort dispatch,
shared experts, expert parallelism over the "model" mesh axis.

Dispatch is sort-based (Megablocks-style, no (T,E,C) one-hot): token→expert
assignments are sorted by expert id, each token's slot is its rank within
its expert segment (capacity-dropped beyond C), and experts run as one
batched einsum over the (E, C, D) buffer.  With experts sharded on "model"
and tokens on "batch", XLA emits the expected all_to_all pair.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import act_fn
from repro.parallel.sharding import constrain


def init_moe_params(rng, cfg: ModelConfig, dtype) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.d_expert
    keys = jax.random.split(rng, 7)
    s = d ** -0.5
    p = {
        "router": (jax.random.normal(keys[0], (d, e.n_experts))
                   * s).astype(jnp.float32),
        "we1": (jax.random.normal(keys[1], (e.n_experts, d, f))
                * s).astype(dtype),
        "we3": (jax.random.normal(keys[2], (e.n_experts, d, f))
                * s).astype(dtype),
        "we2": (jax.random.normal(keys[3], (e.n_experts, f, d))
                * f ** -0.5).astype(dtype),
    }
    if e.n_shared:
        fs = f * e.n_shared
        p.update({
            "ws1": (jax.random.normal(keys[4], (d, fs)) * s).astype(dtype),
            "ws3": (jax.random.normal(keys[5], (d, fs)) * s).astype(dtype),
            "ws2": (jax.random.normal(keys[6], (fs, d))
                    * fs ** -0.5).astype(dtype),
        })
    return p


def _route(p: dict, xf: jnp.ndarray, cfg: ModelConfig):
    e = cfg.moe
    logits = xf.astype(jnp.float32) @ p["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, e.top_k)             # (T, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], e.n_experts), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = e.n_experts * jnp.sum(density * mean_prob) * e.aux_loss_weight
    return top_p, top_e, aux


def _dispatch_compute_combine(xf, top_e, top_p, we1, we3, we2, cfg,
                              n_experts: int, expert_offset=0):
    """Capacity-bounded sort dispatch → batched expert einsums → combine.

    Runs on *local* data under shard_map (expert_offset selects this
    shard's expert range) or globally in the GSPMD baseline."""
    e = cfg.moe
    t, d = xf.shape
    k = e.top_k
    cap = int(e.capacity_factor * t * k / e.n_experts)
    cap = max(8, -(-cap // 8) * 8)
    flat_e = top_e.reshape(-1) - expert_offset               # (T·k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_p = top_p.reshape(-1)
    local = (flat_e >= 0) & (flat_e < n_experts)
    flat_e = jnp.where(local, flat_e, n_experts)             # trash expert
    order = jnp.argsort(flat_e)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    seg_start = jnp.searchsorted(se, jnp.arange(n_experts + 1))
    rank = jnp.arange(t * k) - seg_start[jnp.clip(se, 0, n_experts)]
    keep = (rank < cap) & (se < n_experts)
    slot = jnp.where(keep, rank, cap)
    buf = jnp.zeros((n_experts + 1, cap + 1, d), xf.dtype)
    buf = buf.at[jnp.clip(se, 0, n_experts), slot].set(xf[st])
    hb = buf[:n_experts, :cap]
    h = act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", hb, we1)) \
        * jnp.einsum("ecd,edf->ecf", hb, we3)
    out_buf = jnp.einsum("ecf,efd->ecd", h, we2)
    out_buf = jnp.pad(out_buf, ((0, 1), (0, 1), (0, 0)))
    gathered = out_buf[jnp.clip(se, 0, n_experts), slot]     # (T·k, D)
    w = (sp * keep).astype(gathered.dtype)[:, None]
    return jnp.zeros((t, d), gathered.dtype).at[st].add(gathered * w)


def _moe_shmap(p: dict, x: jnp.ndarray, top_e, top_p, cfg: ModelConfig):
    """Explicit EP: experts sharded on "model", tokens model-replicated;
    combine = one psum over the model axis."""
    e = cfg.moe
    mesh = jax.sharding.get_abstract_mesh()
    axes = tuple(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in axes)
    tp = mesh.shape["model"]
    e_loc = e.n_experts // tp
    b, s, d = x.shape
    from jax.sharding import PartitionSpec as P

    def body(xl, tel, tpl, we1, we3, we2):
        t_loc = xl.shape[0] * xl.shape[1]
        off = jax.lax.axis_index("model") * e_loc
        y = _dispatch_compute_combine(
            xl.reshape(t_loc, d), tel.reshape(t_loc, -1),
            tpl.reshape(t_loc, -1), we1, we3, we2, cfg, e_loc, off)
        return jax.lax.psum(y, "model").reshape(xl.shape)

    dp_spec = dp if dp else None
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_spec), P(dp_spec), P(dp_spec),
                  P("model"), P("model"), P("model")),
        out_specs=P(dp_spec), check_vma=False)
    return fn(x, top_e.reshape(b, s, -1), top_p.reshape(b, s, -1),
              p["we1"], p["we3"], p["we2"])


def moe_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) → (y, aux_loss)."""
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    top_p, top_e, aux = _route(p, xf, cfg)

    mesh = jax.sharding.get_abstract_mesh()
    use_shmap = (cfg.moe_shmap and mesh is not None
                 and not getattr(mesh, "empty", True)
                 and "model" in mesh.axis_names
                 and e.n_experts % mesh.shape["model"] == 0)
    if use_shmap:
        y = _moe_shmap(p, x, top_e, top_p, cfg).reshape(b, s, d)
        y = constrain(y, "batch", None, None)
    else:
        # GSPMD baseline: global capacity dispatch, sharding constraints
        # request EP on "model" (the partitioner's scatter handling is
        # exactly what the §Perf log measures against the shard_map path)
        y = _dispatch_compute_combine(xf, top_e, top_p, p["we1"],
                                      p["we3"], p["we2"], cfg,
                                      e.n_experts)
        y = constrain(y.reshape(b, s, d), "batch", None, None)

    # --- shared experts --------------------------------------------------------
    if e.n_shared:
        hs = act_fn(cfg.act)(xf @ p["ws1"]) * (xf @ p["ws3"])
        y = y + (hs @ p["ws2"]).reshape(b, s, d)
    return y.astype(x.dtype), aux
