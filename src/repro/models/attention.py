"""GQA attention: blockwise (flash-style) training/prefill + cached decode.

Blockwise attention keeps activation memory linear in sequence length
(online softmax over KV blocks, fp32 accumulators) — required for the
prefill_32k cells, where materialized (S, S) scores would be TB-scale.
Supports causal, sliding-window (Mixtral/Gemma2 local) and bidirectional
(HuBERT encoder) masking, attn-logit softcap (Gemma2), and GQA head groups.

Caches:
  full    (B, S_max, KV, dh) k/v, absolute write position
  window  ring buffer (B, W, KV, dh) + per-slot absolute positions — bounds
          long_500k cells for SWA / hybrid archs
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rope_freqs, softcap
from repro.parallel.sharding import constrain

NEG_INF = -1e30


def init_attn_params(rng, cfg: ModelConfig, dtype) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, h * dh)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, kv * dh)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, kv * dh)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (h * dh, d)) * s).astype(dtype),
    }


def _project_qkv(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                 positions: jnp.ndarray):
    b, s, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(b, s, h, dh)
    k = (x @ p["wk"]).reshape(b, s, kv, dh)
    v = (x @ p["wv"]).reshape(b, s, kv, dh)
    angles = rope_freqs(positions, dh, cfg.rope_theta)
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    return q, k, v


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        cap: float = 0.0, q_offset: int = 0,
                        q_block: int = 512, kv_block: int = 1024,
                        scale: float | None = None,
                        preferred: bool = False) -> jnp.ndarray:
    """q (B,Sq,H,dh), k (B,Skv,KV,dh), v (B,Skv,KV,dv) → (B,Sq,H,dv).

    Online softmax; dv may differ from dh (MLA)."""
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    scale = dh ** -0.5 if scale is None else scale
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    nq = -(-sq // qb)
    nk = -(-skv // kb)
    qpad, kpad = nq * qb - sq, nk * kb - skv
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    qr = q.reshape(b, nq, qb, kvh, g, dh).swapaxes(0, 1)   # (nq,B,qb,KV,G,dh)
    kr = k.reshape(b, nk, kb, kvh, dh).swapaxes(0, 1)       # (nk,B,kb,KV,dh)
    vr = v.reshape(b, nk, kb, kvh, dv).swapaxes(0, 1)

    def q_step(qi, qblk):
        qpos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(carry, args):
            ki, kblk, vblk = args
            m, l, acc = carry
            kpos = ki * kb + jnp.arange(kb)
            if preferred:
                s_ = jnp.einsum("bqkgd,bskd->bqkgs", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            else:
                s_ = jnp.einsum("bqkgd,bskd->bqkgs",
                                qblk.astype(jnp.float32),
                                kblk.astype(jnp.float32)) * scale
            s_ = softcap(s_, cap)
            mask = (kpos[None, :] < skv) & jnp.ones((qb, 1), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s_ = jnp.where(mask[None, :, None, None, :], s_, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_, axis=-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            if preferred:
                pv = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(vblk.dtype),
                                vblk, preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bqkgs,bskd->bqkgd", p,
                                vblk.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, qb, kvh, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qb, kvh, g), jnp.float32)
        a0 = jnp.zeros((b, qb, kvh, g, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, qb, h, dv)

    out = jax.lax.map(lambda args: q_step(*args),
                      (jnp.arange(nq), qr))                 # (nq,B,qb,H,dv)
    out = out.swapaxes(0, 1).reshape(b, nq * qb, h, dv)
    return out[:, :sq].astype(q.dtype)


def attn_train(p: dict, x: jnp.ndarray, positions: jnp.ndarray,
               cfg: ModelConfig, *, window: int = 0,
               bidirectional: bool = False) -> jnp.ndarray:
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    y = blockwise_attention(q, k, v, causal=not bidirectional,
                            window=window, cap=cfg.attn_softcap,
                            preferred=cfg.accum_via_preferred)
    y = constrain(y, "batch", None, "model", None)
    return y.reshape(b, s, -1) @ p["wo"]


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def quantize_kv(x: jnp.ndarray):
    """Per-(token, head) symmetric int8: x (B,S,KV,dh) → (int8, bf16 scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    return q.astype(dtype) * scale.astype(dtype)


def init_full_cache(b: int, s_max: int, cfg: ModelConfig, dtype) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.d_head
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros((b, s_max, kv, dh), jnp.int8),
                "v": jnp.zeros((b, s_max, kv, dh), jnp.int8),
                "k_scale": jnp.zeros((b, s_max, kv, 1), jnp.bfloat16),
                "v_scale": jnp.zeros((b, s_max, kv, 1), jnp.bfloat16)}
    return {"k": jnp.zeros((b, s_max, kv, dh), dtype),
            "v": jnp.zeros((b, s_max, kv, dh), dtype)}


def init_window_cache(b: int, window: int, cfg: ModelConfig, dtype) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.d_head
    return {"k": jnp.zeros((b, window, kv, dh), dtype),
            "v": jnp.zeros((b, window, kv, dh), dtype),
            "pos": jnp.full((window,), -1, jnp.int32)}


def attn_prefill(p: dict, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig, *, window: int = 0,
                 cache: dict | None = None
                 ) -> tuple[jnp.ndarray, dict | None]:
    """Causal forward that also fills the cache (cache may be None)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    y = blockwise_attention(q, k, v, causal=True, window=window,
                            cap=cfg.attn_softcap,
                            preferred=cfg.accum_via_preferred)
    new_cache = None
    if cache is not None:
        if "pos" in cache:  # ring/window cache: keep last W positions
            w = cache["k"].shape[1]
            take = min(w, s)
            slots = positions[-take:] % w
            new_cache = {
                "k": cache["k"].at[:, slots].set(k[:, -take:]),
                "v": cache["v"].at[:, slots].set(v[:, -take:]),
                "pos": cache["pos"].at[slots].set(positions[-take:]),
            }
        elif "k_scale" in cache:   # int8 cache
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            new_cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], kq,
                                                  (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], vq,
                                                  (0, 0, 0, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, 0, 0, 0)),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, 0, 0, 0)),
            }
        else:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], k, (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], v, (0, 0, 0, 0)),
            }
    return y.reshape(b, s, -1) @ p["wo"], new_cache


def attn_decode(p: dict, x: jnp.ndarray, pos: jnp.ndarray, cache: dict,
                cfg: ModelConfig, *, window: int = 0
                ) -> tuple[jnp.ndarray, dict]:
    """One-token decode against a full or window cache.

    x (B, 1, D); pos scalar int32 (absolute position of the new token).
    """
    b = x.shape[0]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    g = h // kvh
    q = (x @ p["wq"]).reshape(b, 1, h, dh)
    k = (x @ p["wk"]).reshape(b, 1, kvh, dh)
    v = (x @ p["wv"]).reshape(b, 1, kvh, dh)
    angles = rope_freqs(pos[None], dh, cfg.rope_theta)      # (1, dh/2)
    q = apply_rope(q, angles[None])
    k = apply_rope(k, angles[None])
    if "pos" in cache:  # ring cache
        w = cache["k"].shape[1]
        slot = pos % w
        ck = cache["k"].at[:, slot].set(k[:, 0])
        cv = cache["v"].at[:, slot].set(v[:, 0])
        cpos = cache["pos"].at[slot].set(pos)
        valid = (cpos >= 0) & (cpos > pos - (window or w)) & (cpos <= pos)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        keys, vals, kmask = ck, cv, valid
    elif "k_scale" in cache:   # int8 cache: quantized write, dequant read
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq,
                                              (0, pos, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq,
                                              (0, pos, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, pos, 0, 0)),
            "v_scale": jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, pos, 0, 0)),
        }
        s_max = new_cache["k"].shape[1]
        kmask = jnp.arange(s_max) <= pos
        if window:
            kmask &= jnp.arange(s_max) > pos - window
        keys = dequantize_kv(new_cache["k"], new_cache["k_scale"], x.dtype)
        vals = dequantize_kv(new_cache["v"], new_cache["v_scale"], x.dtype)
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        s_max = ck.shape[1]
        kmask = jnp.arange(s_max) <= pos
        if window:
            kmask &= jnp.arange(s_max) > pos - window
        new_cache = {"k": ck, "v": cv}
        keys, vals = ck, cv
    from repro.models.layers import einsum_f32
    qf = q.reshape(b, kvh, g, dh)
    s_ = einsum_f32("bkgd,bskd->bkgs", qf, keys,
                    cfg.accum_via_preferred) * (dh ** -0.5)
    s_ = softcap(s_, cfg.attn_softcap)
    s_ = jnp.where(kmask[None, None, None, :], s_, NEG_INF)
    pattn = jax.nn.softmax(s_, axis=-1)
    if cfg.accum_via_preferred:
        y = jnp.einsum("bkgs,bskd->bkgd", pattn.astype(x.dtype), vals,
                       preferred_element_type=jnp.float32)
    else:
        y = jnp.einsum("bkgs,bskd->bkgd", pattn,
                       vals.astype(jnp.float32))
    y = y.reshape(b, 1, h * dh).astype(x.dtype)
    return y @ p["wo"], new_cache
