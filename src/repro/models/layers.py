"""Shared layers: norms, rope, MLP, embeddings, losses."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def rope_freqs(positions: jnp.ndarray, dim: int,
               theta: float) -> jnp.ndarray:
    """positions (...,) int32 → angles (..., dim//2) float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, dh); angles (..., S, dh//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = jnp.cos(angles)[..., None, :]
    s = jnp.sin(angles)[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def einsum_f32(subscripts: str, a: jnp.ndarray, b: jnp.ndarray,
               preferred: bool) -> jnp.ndarray:
    """f32-result einsum; ``preferred`` keeps bf16 inputs on the MXU with
    f32 accumulation instead of materializing f32 operand copies."""
    if preferred:
        return jnp.einsum(subscripts, a, b,
                          preferred_element_type=jnp.float32)
    return jnp.einsum(subscripts, a.astype(jnp.float32),
                      b.astype(jnp.float32))


def mlp(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray, w2: jnp.ndarray,
        act: str) -> jnp.ndarray:
    """Gated MLP: w2( act(x·w1) * (x·w3) )."""
    h = act_fn(act)(x @ w1) * (x @ w3)
    return h @ w2


def embed(tokens: jnp.ndarray, table: jnp.ndarray,
          config: ModelConfig) -> jnp.ndarray:
    x = jnp.take(table, tokens, axis=0).astype(
        dtype_of(config.compute_dtype))
    if config.embed_scale:
        x = x * jnp.asarray(config.d_model ** 0.5, x.dtype)
    return x


def chunked_cross_entropy(h: jnp.ndarray, table: jnp.ndarray,
                          labels: jnp.ndarray, config: ModelConfig,
                          mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean CE without materializing (B, S, V) logits.

    h (B, S, D); labels (B, S); logits computed per sequence chunk in fp32
    with optional final softcap (gemma2).  256k-vocab × 1M-token cells would
    otherwise need TB-scale logit buffers.
    """
    b, s, d = h.shape
    chunk = min(config.loss_chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = h.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)
    if mask is None:
        mc = (lc >= 0)
    else:
        mc = mask.reshape(b, n_chunks, chunk).swapaxes(0, 1) & (lc >= 0)

    def one(args):
        hi, li, mi = args
        logits = hi.astype(jnp.float32) @ table.T.astype(jnp.float32)
        logits = softcap(logits, config.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        return jnp.sum(jnp.where(mi, lse - ll, 0.0)), jnp.sum(mi)

    losses, counts = jax.lax.map(one, (hc, lc, mc))
    return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1)


def final_logits(h: jnp.ndarray, table: jnp.ndarray,
                 config: ModelConfig) -> jnp.ndarray:
    """Decode-time logits (B, 1, V) — tiny, full vocab is fine."""
    logits = h.astype(jnp.float32) @ table.T.astype(jnp.float32)
    return softcap(logits, config.final_softcap)
