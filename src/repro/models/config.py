"""Model configuration for the unified architecture family."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0            # shared (always-on) experts
    first_dense: int = 0         # leading dense layers (DeepSeek-V3: 3)
    dense_d_ff: int = 0          # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    d_conv: int = 4
    chunk: int = 256
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | vlm | audio | ssm | moe | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0              # default d_model // n_heads

    # layer pattern: cycled over layers. entries: "full" | "window" | "ssm"
    block_pattern: tuple[str, ...] = ("full",)
    window: int = 4096
    # hybrid (Zamba2): a weight-shared full-attention block applied every
    # shared_attn_every SSM layers
    shared_attn_every: int = 0

    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_theta: float = 10_000.0
    act: str = "silu"            # silu | gelu
    use_post_norm: bool = False  # gemma2 sandwich norms
    encoder_only: bool = False
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma2 scales embeddings by sqrt(d)

    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    mtp: bool = False            # DeepSeek-V3 multi-token-prediction head

    # modality frontends are stubs: input_specs() provides embeddings
    frontend: str = "none"       # none | vision | audio
    n_frontend_tokens: int = 0   # vision: image tokens prepended

    # numerics / memory policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"          # full | dots | none
    loss_chunk: int = 512        # vocab-logit sequence chunking
    # §Perf lever: keep matmul inputs in bf16 and accumulate in f32 via
    # preferred_element_type instead of casting inputs to f32 (the naive
    # baseline materializes f32 copies of large operands, e.g. KV caches)
    accum_via_preferred: bool = False
    # §Perf lever: explicit shard_map MoE — each model shard runs its local
    # experts over its (model-replicated) tokens and the combine is one
    # psum, instead of GSPMD lowering the capacity scatter to a replicated
    # all-reduce of the (E, C, D) dispatch buffer
    moe_shmap: bool = False
    # §Perf lever (decode): int8 full-attention KV cache with per-(token,
    # head) scales — halves the cache-read bytes that dominate decode cells
    kv_cache_dtype: str = "bfloat16"      # "bfloat16" | "int8"

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads > 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def head_groups(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    def layer_kinds(self) -> tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.n_layers))

    def is_sub_quadratic(self) -> bool:
        """True if long-context decode is tractable: no unbounded-cache
        full-attention layers (SSM, window-only, or hybrid w/ window)."""
        kinds = set(self.layer_kinds())
        if self.shared_attn_every:   # hybrid: shared attn gets windowed cache
            return "full" not in kinds
        return "full" not in kinds

    def param_count(self) -> int:
        """Approximate parameter count (reported in the roofline table)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                       # embedding (tied head)
        if not self.tie_embeddings:
            total += v * d
        kinds = self.layer_kinds()
        for i, kind in enumerate(kinds):
            total += 2 * d                  # norms
            if kind == "ssm":
                s = self.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                g = s.n_groups
                total += d * (2 * di + 2 * g * s.d_state + nh)  # in_proj
                total += s.d_conv * (di + 2 * g * s.d_state)    # conv
                total += 2 * nh + nh                            # A, D, dt_bias
                total += di * d                                 # out_proj
                continue
            # attention
            if self.mla is not None:
                m = self.mla
                qd = m.qk_nope_dim + m.qk_rope_dim
                total += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qd
                total += d * (m.kv_lora_rank + m.qk_rope_dim)
                total += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim
                                                          + m.v_head_dim)
                total += self.n_heads * m.v_head_dim * d
            else:
                total += d * self.n_heads * self.d_head          # wq
                total += 2 * d * self.n_kv_heads * self.d_head   # wk, wv
                total += self.n_heads * self.d_head * d          # wo
            # ffn / moe
            if self.moe is not None and i >= self.moe.first_dense:
                e = self.moe
                total += d * e.n_experts                        # router
                total += (e.n_experts + e.n_shared) * 3 * d * e.d_expert
            else:
                ff = (self.moe.dense_d_ff if self.moe is not None
                      else self.d_ff)
                total += 3 * d * ff
        if self.shared_attn_every:
            # one weight-shared attention+mlp block
            total += d * self.n_heads * self.d_head * 2 \
                + 2 * d * self.n_kv_heads * self.d_head + 3 * d * self.d_ff
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total = self.param_count()
        moe_layers = self.n_layers - e.first_dense
        all_routed = moe_layers * e.n_experts * 3 * self.d_model * e.d_expert
        act_routed = moe_layers * e.top_k * 3 * self.d_model * e.d_expert
        return int(total - all_routed + act_routed)
