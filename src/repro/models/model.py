"""Model facade: init / train_loss / prefill / decode_step for all families.

Batch contracts (see configs.shapes.input_specs):
  LM:     {"tokens": (B,S) i32, "labels": (B,S) i32}
  VLM:    + {"img_embeds": (B, n_img, D) compute-dtype}; tokens fill S-n_img
  audio:  {"frames": (B,S,D), "labels": (B,S) i32, "mask": (B,S) bool}
Decode:   token (B,1) i32, pos () i32, caches pytree (stacked per segment).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.blocks import (Segment, block_forward, init_block_cache,
                                 init_block_params, init_segment_params,
                                 layer_plan, segment_forward)
from repro.models.config import ModelConfig
from repro.models.layers import (chunked_cross_entropy, dtype_of, embed,
                                 final_logits, rms_norm)
from repro.parallel.sharding import constrain

MTP_WEIGHT = 0.1
SHARED_ATTN_DECODE_WINDOW = 4096   # hybrid long-context cache bound


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.segments: list[Segment] = layer_plan(cfg)

    # -- init -------------------------------------------------------------

    def init(self, rng) -> dict:
        cfg = self.cfg
        dtype = dtype_of(cfg.param_dtype)
        keys = jax.random.split(rng, len(self.segments) + 4)
        params: dict = {
            "embed": (jax.random.normal(
                keys[0], (cfg.vocab_size, cfg.d_model))
                * cfg.d_model ** -0.5).astype(dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
            "segments": [init_segment_params(keys[i + 1], cfg, seg)
                         for i, seg in enumerate(self.segments)],
        }
        if cfg.shared_attn_every:
            params["shared_attn"] = init_block_params(
                keys[-3], cfg, "full", "dense", cfg.d_ff)
        if cfg.mtp:
            params["mtp"] = init_block_params(
                keys[-2], cfg, "full",
                "moe" if cfg.moe is not None else "dense",
                cfg.d_ff)
            params["mtp_norm"] = jnp.zeros((cfg.d_model,), dtype)
        return params

    # -- shared forward ----------------------------------------------------------

    def _inputs(self, params: dict, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.frontend == "audio":
            return batch["frames"].astype(dtype_of(cfg.compute_dtype))
        x = embed(batch["tokens"], params["embed"], cfg)
        if cfg.frontend == "vision":
            img = batch["img_embeds"].astype(x.dtype)
            x = jnp.concatenate([img, x], axis=1)
        return constrain(x, "batch", None, None)

    def _backbone(self, params: dict, x: jnp.ndarray, *, mode: str,
                  caches=None, pos=None):
        cfg = self.cfg
        s = x.shape[1]
        positions = (jnp.arange(s, dtype=jnp.int32) if mode != "decode"
                     else None)
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = {"segments": [], "shared": []}
        shared_params = params.get("shared_attn")
        shared_window = (SHARED_ATTN_DECODE_WINDOW
                         if mode != "train" else None)
        for i, seg in enumerate(self.segments):
            seg_cache = None if caches is None else caches["segments"][i]
            sh_cache = None if caches is None else caches["shared"][i]
            x, nc, nsh, aux = segment_forward(
                params["segments"][i], x, cfg, seg, positions, mode=mode,
                caches=seg_cache, pos=pos, shared_params=shared_params,
                shared_caches=sh_cache, bidirectional=cfg.encoder_only,
                shared_window=shared_window)
            aux_total = aux_total + aux
            new_caches["segments"].append(nc)
            new_caches["shared"].append(nsh)
        h = rms_norm(x, params["final_norm"])
        return h, (new_caches if caches is not None else None), aux_total

    # -- training ------------------------------------------------------------------

    def train_loss(self, params: dict, batch: dict
                   ) -> tuple[jnp.ndarray, dict]:
        cfg = self.cfg
        x = self._inputs(params, batch)
        h, _, aux = self._backbone(params, x, mode="train")
        labels = batch["labels"]
        mask = batch.get("mask")
        if cfg.frontend == "vision":
            # image positions carry no next-token loss
            n_img = cfg.n_frontend_tokens
            pad = jnp.full((labels.shape[0], n_img), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        loss = chunked_cross_entropy(h, params["embed"], labels, cfg,
                                     mask=mask)
        metrics = {"ce_loss": loss, "aux_loss": aux}
        if cfg.mtp:
            # MTP: predict t+2 from h_t + emb(t+1)  (one extra block)
            emb_next = embed(batch["tokens"], params["embed"], cfg)
            h_in = rms_norm(h, params["mtp_norm"]) \
                + jnp.roll(emb_next, -1, axis=1)
            positions = jnp.arange(h.shape[1], dtype=jnp.int32)
            h2, _, aux2 = block_forward(
                params["mtp"], h_in, cfg, "full",
                "moe" if cfg.moe is not None else "dense", positions,
                mode="train")
            mtp_labels = jnp.roll(labels, -1, axis=1).at[:, -1].set(-1)
            mtp_loss = chunked_cross_entropy(h2, params["embed"],
                                             mtp_labels, cfg)
            metrics["mtp_loss"] = mtp_loss
            loss = loss + MTP_WEIGHT * mtp_loss
            aux = aux + aux2
        total = loss + aux
        metrics["loss"] = total
        return total, metrics

    # -- serving ---------------------------------------------------------------------

    def init_caches(self, b: int, s_max: int) -> dict:
        cfg = self.cfg
        caches = {"segments": [], "shared": []}
        for seg in self.segments:
            def stack(tree):
                return jax.tree.map(
                    lambda a: jnp.zeros((seg.steps,) + a.shape, a.dtype),
                    tree)

            step_cache = {
                f"pos{i}": init_block_cache(cfg, kind, b, s_max)
                for i, kind in enumerate(seg.kinds)}
            caches["segments"].append(stack(step_cache))
            if seg.shared_attn:
                sh = init_block_cache(
                    cfg, "full", b, s_max,
                    window_override=SHARED_ATTN_DECODE_WINDOW)
                caches["shared"].append(stack(sh))
            else:
                caches["shared"].append(None)
        return caches

    def encode(self, params: dict, batch: dict) -> jnp.ndarray:
        """Encoder forward (no cache) — prefill analogue for encoder-only
        archs and the backbone of the prefill dry-run cells."""
        x = self._inputs(params, batch)
        h, _, _ = self._backbone(params, x, mode="train")
        return h

    def prefill(self, params: dict, batch: dict, caches: dict
                ) -> tuple[jnp.ndarray, dict]:
        x = self._inputs(params, batch)
        h, new_caches, _ = self._backbone(params, x, mode="prefill",
                                          caches=caches)
        logits = final_logits(h[:, -1:], params["embed"], self.cfg)
        return logits[:, 0], new_caches

    def decode_step(self, params: dict, token: jnp.ndarray,
                    pos: jnp.ndarray, caches: dict
                    ) -> tuple[jnp.ndarray, dict]:
        if self.cfg.encoder_only:
            raise ValueError("encoder-only archs have no decode step")
        x = embed(token, params["embed"], self.cfg)
        h, new_caches, _ = self._backbone(params, x, mode="decode",
                                          caches=caches, pos=pos)
        logits = final_logits(h, params["embed"], self.cfg)
        return logits[:, 0], new_caches
