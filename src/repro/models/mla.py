"""Multi-head Latent Attention (DeepSeek-V3).

Train/prefill run the expanded form through blockwise attention; decode uses
the *absorbed* form against the compressed cache — per-token cache is only
(kv_lora_rank + qk_rope_dim) elements, the feature that makes V3's 128-head
attention serveable.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF, blockwise_attention
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rms_norm, rope_freqs
from repro.parallel.sharding import constrain


def init_mla_params(rng, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    keys = jax.random.split(rng, 5)
    s = d ** -0.5
    return {
        "wq_a": (jax.random.normal(keys[0], (d, m.q_lora_rank)) * s
                 ).astype(dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": (jax.random.normal(keys[1], (m.q_lora_rank, h * qd))
                 * m.q_lora_rank ** -0.5).astype(dtype),
        "wkv_a": (jax.random.normal(
            keys[2], (d, m.kv_lora_rank + m.qk_rope_dim)) * s).astype(dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wkv_b": (jax.random.normal(
            keys[3], (m.kv_lora_rank, h * (m.qk_nope_dim + m.v_head_dim)))
            * m.kv_lora_rank ** -0.5).astype(dtype),
        "wo": (jax.random.normal(keys[4], (h * m.v_head_dim, d))
               * (h * m.v_head_dim) ** -0.5).astype(dtype),
    }


def _queries(p, x, cfg, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rms_norm(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    qn, qr = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    angles = rope_freqs(positions, m.qk_rope_dim, cfg.rope_theta)
    return qn, apply_rope(qr, angles)


def _latents(p, x, cfg, positions):
    m = cfg.mla
    b, s, _ = x.shape
    ckv_full = x @ p["wkv_a"]
    ckv = rms_norm(ckv_full[..., :m.kv_lora_rank], p["kv_norm"])
    kr = ckv_full[..., m.kv_lora_rank:].reshape(b, s, 1, m.qk_rope_dim)
    angles = rope_freqs(positions, m.qk_rope_dim, cfg.rope_theta)
    return ckv, apply_rope(kr, angles)


def mla_train(p: dict, x: jnp.ndarray, positions: jnp.ndarray,
              cfg: ModelConfig) -> jnp.ndarray:
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qn, qr = _queries(p, x, cfg, positions)
    ckv, kr = _latents(p, x, cfg, positions)
    kv = (ckv @ p["wkv_b"]).reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    kn, v = kv[..., :m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(
        kr, (b, s, h, m.qk_rope_dim))], axis=-1)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    y = blockwise_attention(q, k, v, causal=True,
                            scale=(m.qk_nope_dim + m.qk_rope_dim) ** -0.5,
                            preferred=cfg.accum_via_preferred)
    return y.reshape(b, s, -1) @ p["wo"]


def init_mla_cache(b: int, s_max: int, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    return {"ckv": jnp.zeros((b, s_max, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((b, s_max, m.qk_rope_dim), dtype)}


def mla_prefill(p: dict, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, cache: dict | None = None
                ) -> tuple[jnp.ndarray, dict | None]:
    y = mla_train(p, x, positions, cfg)
    new_cache = None
    if cache is not None:
        ckv, kr = _latents(p, x, cfg, positions)
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
            "krope": jax.lax.dynamic_update_slice(
                cache["krope"], kr[:, :, 0].astype(cache["krope"].dtype),
                (0, 0, 0)),
        }
    return y, new_cache


def mla_decode(p: dict, x: jnp.ndarray, pos: jnp.ndarray, cache: dict,
               cfg: ModelConfig) -> tuple[jnp.ndarray, dict]:
    """Absorbed decode: scores/context via the compressed latent cache."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    qn, qr = _queries(p, x, cfg, pos[None])          # (B,1,H,·)
    ckv_new, kr_new = _latents(p, x, cfg, pos[None])
    cache = {
        "ckv": jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0)),
        "krope": jax.lax.dynamic_update_slice(
            cache["krope"], kr_new[:, :, 0].astype(cache["krope"].dtype),
            (0, pos, 0)),
    }
    from repro.models.layers import einsum_f32
    pref = cfg.accum_via_preferred
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, h,
                               m.qk_nope_dim + m.v_head_dim)
    w_kn = wkv_b[..., :m.qk_nope_dim]                # (r, H, dn)
    w_v = wkv_b[..., m.qk_nope_dim:]                 # (r, H, dv)
    q_abs = einsum_f32("bqhd,rhd->bqhr", qn, w_kn, pref)
    ckv, krope = cache["ckv"], cache["krope"]
    if not pref:
        ckv = ckv.astype(jnp.float32)
        krope = krope.astype(jnp.float32)
    s_ = (einsum_f32("bqhr,bsr->bqhs", q_abs.astype(
        ckv.dtype if pref else jnp.float32), ckv, pref)
        + einsum_f32("bqhd,bsd->bqhs", qr, krope, pref))
    s_ = s_ * (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    mask = jnp.arange(cache["ckv"].shape[1]) <= pos
    s_ = jnp.where(mask[None, None, None, :], s_, NEG_INF)
    attn = jax.nn.softmax(s_, axis=-1)
    ctx = einsum_f32("bqhs,bsr->bqhr",
                     attn.astype(ckv.dtype) if pref else attn, ckv, pref)
    y = einsum_f32("bqhr,rhd->bqhd",
                   ctx.astype(w_v.dtype) if pref else ctx, w_v, pref)
    y = y.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    return y @ p["wo"], cache
