"""Mamba2 (SSD — state-space duality) blocks.

Training/prefill use the chunked SSD algorithm: within a chunk the dual
(quadratic) form runs as batched einsums; across chunks a lax.scan carries
the (H, P, N) state — linear in sequence length, which is what makes the
long_500k cells tractable.  Decode is the O(1) recurrent update.

Layout: d_inner = expand·d_model channels split into H heads of P=head_dim;
B/C are shared across heads per group (n_groups=1 here, like Mamba2-2.7B).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm
from repro.parallel.sharding import constrain


def init_ssm_params(rng, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_ch = di + 2 * s.n_groups * s.d_state
    keys = jax.random.split(rng, 4)
    return {
        "in_proj": (jax.random.normal(
            keys[0], (d, 2 * di + 2 * s.n_groups * s.d_state + nh))
            * d ** -0.5).astype(dtype),
        "conv": (jax.random.normal(keys[1], (s.d_conv, conv_ch))
                 * s.d_conv ** -0.5).astype(dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),      # A = -exp(a_log) ≈ -1
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "out_proj": (jax.random.normal(keys[3], (di, d))
                     * di ** -0.5).astype(dtype),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d: xbc (B,S,C), w (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + pad[:, i:i + xbc.shape[1]] * w[i]
    return jax.nn.silu(out)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                bb: jnp.ndarray, cc: jnp.ndarray, chunk: int,
                init_state: jnp.ndarray | None = None
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """SSD over one sequence batch.

    x  (B,S,H,P)   dt (B,S,H) post-softplus   a (H,) negative
    bb/cc (B,S,N)  (single group)
    → (y (B,S,H,P), final_state (B,H,P,N))
    """
    b, s, h, p = x.shape
    n = bb.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bb = jnp.pad(bb, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(b, nc, q, h, p).swapaxes(0, 1)       # (nc,B,q,H,P)
    dtc = dt.reshape(b, nc, q, h).swapaxes(0, 1)
    bc = bb.reshape(b, nc, q, n).swapaxes(0, 1)
    cchunk = cc.reshape(b, nc, q, n).swapaxes(0, 1)
    tri = jnp.tril(jnp.ones((q, q), bool))

    def step(state, args):
        xq, dtq, bq, cq = args                          # (B,q,·)
        da = dtq * a[None, None, :]                     # (B,q,H) ≤ 0
        seg = jnp.cumsum(da, axis=1)                    # (B,q,H)
        total = seg[:, -1]                              # (B,H)
        # intra-chunk (dual/quadratic form)
        ldecay = jnp.exp(seg[:, :, None, :] - seg[:, None, :, :])  # (B,i,j,H)
        ldecay = jnp.where(tri[None, :, :, None], ldecay, 0.0)
        cbt = jnp.einsum("bin,bjn->bij", cq, bq)        # (B,i,j)
        dtx = dtq[..., None] * xq                       # (B,q,H,P)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cbt, ldecay,
                             dtx.astype(jnp.float32))
        # inter-chunk via carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cq, state,
                             jnp.exp(seg))
        # local end-state and carry update
        w = jnp.exp(total[:, None] - seg) * dtq         # (B,q,H)
        s_local = jnp.einsum("bqn,bqh,bqhp->bhpn", bq, w,
                             xq.astype(jnp.float32))
        new_state = jnp.exp(total)[..., None, None] * state + s_local
        return new_state, (y_intra + y_inter).astype(x.dtype)

    state0 = (init_state.astype(jnp.float32) if init_state is not None
              else jnp.zeros((b, h, p, n), jnp.float32))
    final, ys = jax.lax.scan(
        step, state0,
        (xc.astype(jnp.float32), dtc.astype(jnp.float32),
         bc.astype(jnp.float32), cchunk.astype(jnp.float32)))
    y = ys.swapaxes(0, 1).reshape(b, nc * q, h, p)[:, :s]
    return y, final


def _split_proj(proj: jnp.ndarray, cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = s.n_groups * s.d_state
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * gn]
    dt = proj[..., di + di + 2 * gn:]
    return z, xbc, dt


def init_ssm_state(b: int, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    conv_ch = di + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((b, s.d_conv - 1, conv_ch), dtype),
        "ssd": jnp.zeros((b, s.n_heads(d), s.head_dim, s.d_state),
                         jnp.float32),
    }


def ssm_forward(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                state: dict | None = None, return_state: bool = False
                ) -> tuple[jnp.ndarray, dict | None]:
    """Train (state=None) or prefill (return_state=True) over (B,S,D)."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    proj = x @ p["in_proj"]
    z, xbc_raw, dt_raw = _split_proj(proj, cfg)
    xbc = _causal_conv(xbc_raw, p["conv"])
    xs = xbc[..., :di].reshape(b, s, nh, s_cfg.head_dim)
    bbc = xbc[..., di:di + s_cfg.d_state]
    ccc = xbc[..., di + s_cfg.d_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    init = state["ssd"] if state is not None else None
    y, final = ssd_chunked(xs, dt, a, bbc, ccc, s_cfg.chunk, init)
    y = y + p["d_skip"][None, None, :, None].astype(y.dtype) * xs
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"])
    out = y @ p["out_proj"]
    new_state = None
    if return_state:
        k = s_cfg.d_conv - 1
        tail = xbc_raw[:, -k:] if s >= k else jnp.pad(
            xbc_raw, ((0, 0), (k - s, 0), (0, 0)))
        new_state = {"conv": tail.astype(x.dtype), "ssd": final}
    return out, new_state


def ssm_decode(p: dict, x: jnp.ndarray, state: dict, cfg: ModelConfig
               ) -> tuple[jnp.ndarray, dict]:
    """One-token recurrent update. x (B,1,D)."""
    s_cfg = cfg.ssm
    b, _, d = x.shape
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    proj = x[:, 0] @ p["in_proj"]                       # (B, ·)
    z, xbc_raw, dt_raw = _split_proj(proj, cfg)
    hist = jnp.concatenate([state["conv"], xbc_raw[:, None]], axis=1)
    w = p["conv"]
    conv_out = jnp.einsum("bkc,kc->bc", hist, w)
    xbc = jax.nn.silu(conv_out)
    xs = xbc[..., :di].reshape(b, nh, s_cfg.head_dim)
    bbc = xbc[..., di:di + s_cfg.d_state]
    ccc = xbc[..., di + s_cfg.d_state:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a[None, :])                        # (B,H)
    ssd = state["ssd"] * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xs.astype(jnp.float32),
        bbc.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", ssd, ccc.astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)
                                 ).astype(y.dtype)[:, None], p["gate_norm"])
    out = y @ p["out_proj"]
    return out, {"conv": hist[:, 1:], "ssd": ssd}
