"""Sharding rules: logical axes → PartitionSpec over (pod, data, model).

Logical activation/parameter axes:
  "batch"   data parallel — physical ("pod", "data") when a pod axis exists
  "model"   tensor parallel — attention heads / ffn hidden / vocab / experts
  "fsdp"    parameter sharding over the data axis (ZeRO-style), enabled per
            arch with ``zero=True`` when params+optimizer would not fit TP-only
  None      replicated

``constrain`` is safe anywhere: it is a no-op without an ambient mesh, so
model code is runnable unsharded (tests) and sharded (dry-run/train) from
the same source.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _ambient_axes() -> tuple[str, ...]:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return ()
    if mesh is None or getattr(mesh, "empty", True):
        return ()
    return tuple(mesh.axis_names)


def physical_axes(logical: str | None,
                  mesh_axes: tuple[str, ...]):
    if logical is None:
        return None
    if logical == "batch":
        have = tuple(a for a in ("pod", "data") if a in mesh_axes)
        return have if have else None
    if logical == "fsdp":
        return "data" if "data" in mesh_axes else None
    if logical == "model":
        return "model" if "model" in mesh_axes else None
    raise ValueError(f"unknown logical axis {logical!r}")


def spec(*logical, mesh_axes: tuple[str, ...] | None = None) -> P:
    axes = mesh_axes if mesh_axes is not None else _ambient_axes()
    return P(*[physical_axes(l, axes) for l in logical])


def _ambient_shape() -> dict:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return {}
    if mesh is None or getattr(mesh, "empty", True):
        return {}
    return dict(zip(mesh.axis_names, mesh.shape.values())) \
        if hasattr(mesh.shape, "values") else dict(mesh.shape)


def constrain(x: jnp.ndarray, *logical) -> jnp.ndarray:
    """with_sharding_constraint against the ambient mesh; no-op unsharded.

    Drops any axis whose mesh extent does not divide the tensor dim (e.g.
    8 attention heads on a 16-way model axis) — otherwise the partitioner
    falls back to involuntary full rematerialization."""
    axes = _ambient_axes()
    if not axes:
        return x
    sizes = _ambient_shape()
    phys = []
    for dim, l in zip(x.shape, logical):
        p = physical_axes(l, axes)
        if p is None:
            phys.append(None)
            continue
        names = (p,) if isinstance(p, str) else tuple(p)
        extent = 1
        for n in names:
            extent *= sizes.get(n, 1)
        phys.append(p if extent > 0 and dim % extent == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*phys))


# ---------------------------------------------------------------------------
# parameter specs by path convention
# ---------------------------------------------------------------------------

def _leaf_logical(path: str, ndim: int, zero: bool) -> tuple:
    """Logical axes for a parameter, by naming convention.

    Scanned-layer stacks carry a leading L axis (never sharded).  The rules
    below mirror Megatron TP + optional ZeRO ("fsdp") over the data axis.
    """
    fsdp = "fsdp" if zero else None
    rules = None
    if path.endswith("embed"):
        rules = ("model", fsdp)                       # (V, D)
    elif path.endswith(("wq", "w1", "w3", "wq_b", "wkv_b", "wk", "wv")):
        rules = (fsdp, "model")                       # (D, H·dh) / (D, F)
    elif path.endswith(("wo", "w2", "out_proj")):
        rules = ("model", fsdp)                       # (H·dh, D) / (F, D)
    elif path.endswith(("wq_a", "wkv_a")):
        rules = (fsdp, None)                          # low-rank down-proj
    elif path.endswith("router"):
        rules = (None, None)                          # (D, E) small
    elif path.endswith(("we1", "we3")):
        rules = ("model", fsdp, None)                 # (E, D, F): EP
    elif path.endswith("we2"):
        rules = ("model", None, fsdp)                 # (E, F, D): EP
    elif path.endswith("in_proj"):
        rules = (fsdp, "model")                       # ssm (D, …)
    elif path.endswith("conv"):
        rules = (None, "model")                       # (d_conv, channels)
    elif path.endswith(("a_log", "d_skip", "dt_bias")):
        rules = ("model",)                            # per-head
    elif path.endswith(("scale", "norm", "q_norm", "kv_norm", "gate_norm")):
        rules = (None,)
    if rules is None:
        rules = tuple([None] * ndim)
    if len(rules) < ndim:                             # scanned leading axes
        rules = tuple([None] * (ndim - len(rules))) + tuple(rules)
    return tuple(rules[:ndim])


def _axis_extent(p, sizes) -> int:
    names = (p,) if isinstance(p, str) else tuple(p)
    extent = 1
    for n in names:
        extent *= sizes.get(n, 1)
    return extent


def fit_spec(shape, logical, mesh_axes: tuple[str, ...],
             mesh_sizes: dict) -> P:
    """Divisibility-aware spec: drop axes whose extent does not divide the
    dim; a dropped "model" axis is relocated to another divisible dim
    (e.g. granite's 49155-vocab embedding moves TP to the d_model dim)."""
    phys = [physical_axes(l, mesh_axes) for l in logical]
    dropped_model = False
    for i, (dim, p) in enumerate(zip(shape, phys)):
        if p is None:
            continue
        if dim % _axis_extent(p, mesh_sizes) != 0:
            if p == "model":
                dropped_model = True
            phys[i] = None
    if dropped_model:
        for i, (dim, p) in enumerate(zip(shape, phys)):
            if p is None and dim % _axis_extent("model", mesh_sizes) == 0 \
                    and dim >= _axis_extent("model", mesh_sizes):
                phys[i] = "model"
                break
    return P(*phys)


def param_pspecs(params, zero: bool = False,
                 mesh_axes: tuple[str, ...] | None = None,
                 mesh_sizes: dict | None = None):
    """PartitionSpec pytree mirroring a params pytree (by path rules)."""
    axes = mesh_axes if mesh_axes is not None else _ambient_axes()
    if mesh_sizes is None:
        # production meshes: pod=2, data=16, model=16; local meshes pass
        # their own sizes
        mesh_sizes = {"pod": 2, "data": 16, "model": 16}

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
        if node is None:
            return None
        logical = _leaf_logical(path, node.ndim, zero)
        return fit_spec(node.shape, logical, axes, mesh_sizes)

    return walk(params, "")


def shard_info(params, pspecs) -> dict:
    """Bytes-per-device accounting used by the dry-run report."""
    leaves = jax.tree.leaves(params)
    total = sum(x.size * x.dtype.itemsize if hasattr(x, "dtype") else 0
                for x in leaves)
    return {"param_bytes_total": int(total)}


def contiguous_shards(weights, n: int) -> list[tuple[int, int]]:
    """Split ``len(weights)`` plan-ordered items into ``n`` contiguous
    ``[lo, hi)`` shards with roughly equal total weight.

    The dataset executor feeds plan-ordered (key-range-sorted) fragment
    ``stored_bytes`` through this, so each device scans a contiguous key
    range — locality for pruning and for the in-order reduce.  Boundaries
    sit at the cumulative-weight quantiles; every shard is non-empty while
    items remain (n > len(weights) yields trailing empty shards).  Pure
    and deterministic — the same weights and n always produce the same
    shards, which the bit-identical multi-device reduce relies on.
    """
    m = len(weights)
    n = max(1, n)
    weights = [float(w) for w in weights]
    total = sum(weights)
    shards: list[tuple[int, int]] = []
    lo = 0
    acc = 0.0
    for k in range(n):
        if lo >= m:
            shards.append((m, m))
            continue
        if k == n - 1:
            shards.append((lo, m))
            lo = m
            continue
        # advance while adding the next item keeps us at-or-under the
        # quantile midpoint (half-weight rule balances boundary items)
        target = total * (k + 1) / n
        hi = lo
        while hi < m and (hi == lo or acc + weights[hi] / 2 <= target):
            acc += weights[hi]
            hi += 1
        # leave at least one item for each remaining shard
        hi = min(hi, m - (n - k - 1))
        hi = max(hi, lo + 1)
        acc = sum(weights[:hi])
        shards.append((lo, hi))
        lo = hi
    return shards
