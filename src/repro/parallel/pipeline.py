"""GPipe-style pipeline parallelism via shard_map + ppermute.

Optional module (the production dry-run meshes are DP×TP): demonstrates the
collective-permute microbatch schedule for depth-sharded deployments where
a 1000+-node cluster adds a "stage" mesh axis.

Schedule: T = n_micro + n_stages - 1 ticks.  At tick t, stage s computes
microbatch (t - s) if 0 ≤ t - s < n_micro; activations flow s → s+1 through
ppermute.  Stage 0 injects microbatches; the last stage's outputs are
collected and all-gathered.
"""

from __future__ import annotations

import functools
from collections.abc import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn: Callable, params_stacked, x, *,
                     mesh, n_micro: int, axis: str = "stage"):
    """Run x through n_stages sequential stages with microbatching.

    stage_fn(params_slice, h) -> h    (shape-preserving)
    params_stacked: pytree with leading (n_stages,) axis
    x: (B, ...) with B % n_micro == 0
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_micro == 0
    mb = b // n_micro
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def spmd(params_local, x_all):
        # params_local: this stage's slice (leading axis stripped by shard_map)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        micro = x_all.reshape(n_micro, mb, *x_all.shape[1:])
        ticks = n_micro + n_stages - 1
        buf = jnp.zeros((mb, *x_all.shape[1:]), x_all.dtype)
        outs = jnp.zeros((n_micro, mb, *x_all.shape[1:]), x_all.dtype)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t; others take the permuted input
            inject = micro[jnp.clip(t, 0, n_micro - 1)]
            h_in = jnp.where(stage == 0, inject, buf)
            active = (t - stage >= 0) & (t - stage < n_micro)
            h_out = stage_fn(params_local, h_in)
            h_out = jnp.where(active, h_out, buf)
            # collect finished microbatch at the last stage
            mb_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            take = active & (stage == n_stages - 1)
            outs = jax.lax.cond(
                take,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, mb_idx, 0),
                lambda o: o, outs)
            nxt = jax.lax.ppermute(h_out, axis, perm)
            return (nxt, outs)

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # broadcast the last stage's collected outputs to every stage
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs.reshape(b, *x_all.shape[1:])

    fn = jax.shard_map(
        spmd, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
        check_vma=False)
    return fn(params_stacked, x)


def sequential_reference(stage_fn, params_stacked, x):
    """Oracle: apply the stages one after another."""
    n_stages = jax.tree.leaves(params_stacked)[0].shape[0]
    h = x
    for s in range(n_stages):
        ps = jax.tree.map(lambda a: a[s], params_stacked)
        h = stage_fn(ps, h)
    return h
