"""Explicit collectives: gradient-compressed all-reduce (shard_map).

Under pure pjit the data-parallel gradient all-reduce is implicit (XLA
emits it from the batch-sharded loss).  For 1000+-node DP, compressing that
all-reduce is a standard trick; we implement it honestly via shard_map:

  bf16      grads cast to bf16 for the wire, fp32 restored after
  int8_ef   per-leaf symmetric int8 quantization with a *shared* scale
            (max|g| all-reduced first), int32 wire accumulation, plus
            error-feedback residuals carried in the optimizer state so the
            quantization error is re-injected next step (convergence-safe)

Both halve (or quarter) DP wire bytes — a direct collective-roofline-term
lever recorded in §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def psum_bf16(tree, axis):
    """All-reduce in bf16 (2× wire reduction)."""
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis)
        .astype(jnp.float32), tree)


def psum_int8_ef(tree, axis, error: dict | None) -> tuple[dict, dict]:
    """int8 all-reduce with error feedback.

    Returns (reduced_tree_fp32, new_error_tree).  ``error`` holds last
    step's per-leaf quantization residuals (or None on step 0).
    """
    leaves, treedef = jax.tree.flatten(tree)
    err_leaves = (jax.tree.leaves(error) if error is not None
                  else [jnp.zeros_like(l, jnp.float32) for l in leaves])
    outs, new_errs = [], []
    n_dev = jax.lax.psum(1, axis)
    for g, e in zip(leaves, err_leaves):
        gf = g.astype(jnp.float32) + e
        # shared symmetric scale: the max |g| across the DP group
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        wire = jax.lax.psum(q.astype(jnp.int32), axis)   # ≤ 127·n_dev: safe
        deq = wire.astype(jnp.float32) * scale / n_dev
        local_deq = q.astype(jnp.float32) * scale
        new_errs.append(gf - local_deq)                  # residual carried
        outs.append(deq)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, new_errs))


def reduce_gradients(local_grads, axis: str, method: str,
                     error: dict | None = None):
    """Dispatch used inside the shard_map'd manual-DP train step.

    Returns (mean_grads_fp32, new_error_or_None)."""
    n = jax.lax.psum(1, axis)
    if method == "none":
        return jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.float32), axis) / n,
            local_grads), None
    if method == "bf16":
        return jax.tree.map(
            lambda g: (jax.lax.psum(g.astype(jnp.bfloat16), axis)
                       .astype(jnp.float32) / n), local_grads), None
    if method == "int8_ef":
        return psum_int8_ef(local_grads, axis, error)
    raise ValueError(method)


def tree_reduce(parts, combine):
    """Deterministic balanced binary reduction of per-fragment partials.

    ``parts`` is the *plan-ordered* list of fragment partials — one slot
    per planned fragment, regardless of which device produced it.  The
    tree shape therefore depends only on the plan, never on device count
    or completion order, so the result is bit-identical for devices ∈
    {1, 2, 4, ...}: the floating-point combine sees the exact same
    operand pairing every time.  None entries (quarantined fragments on
    best_effort runs) are dropped before pairing — the same fragments
    are dropped whatever the device count; returns None when nothing
    remains.
    """
    vals = [p for p in parts if p is not None]
    if not vals:
        return None
    while len(vals) > 1:
        nxt = [combine(vals[i], vals[i + 1])
               for i in range(0, len(vals) - 1, 2)]
        if len(vals) % 2:
            nxt.append(vals[-1])
        vals = nxt
    return vals[0]
