# Distribution substrate: sharding rules over the (pod, data, model) mesh,
# compressed collectives, and the optional pipeline-parallel executor.
